//! Experiment/service configuration: a typed config struct parsed from a
//! minimal TOML subset (the offline environment carries no `toml`
//! crate). Supported syntax: `[section]` headers, `key = value` with
//! string/int/float/bool values, `#` comments.

use std::collections::BTreeMap;

/// Parsed raw config: `section.key -> value` (top-level keys live under
/// the empty section).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct RawConfig {
    entries: BTreeMap<String, Value>,
}

/// A TOML-subset scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// Config parse error (line number + reason).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub reason: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.reason)
    }
}

impl std::error::Error for ParseError {}

/// A semantic config error: the file parsed, but a value (or a
/// combination of values) cannot run safely. Unlike the lenient
/// per-key overlay clamps, these are *rejected* — silently "fixing" a
/// reliability or supervision knob would change failure semantics the
/// operator is counting on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidationError {
    /// The `section.key` at fault.
    pub key: String,
    /// Why the value combination is rejected.
    pub reason: String,
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid config: {}: {}", self.key, self.reason)
    }
}

impl std::error::Error for ValidationError {}

impl RawConfig {
    /// Parse from TOML-subset text.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (i, raw_line) in text.lines().enumerate() {
            let line = raw_line.trim();
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or_else(|| ParseError {
                    line: i + 1,
                    reason: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| ParseError {
                line: i + 1,
                reason: format!("expected 'key = value', got {line:?}"),
            })?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            cfg.entries.insert(
                full_key,
                parse_value(value.trim()).map_err(|reason| ParseError {
                    line: i + 1,
                    reason,
                })?,
            );
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.entries.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.entries.get(key) {
            Some(Value::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.entries.get(key) {
            Some(Value::Float(v)) => Some(*v),
            Some(Value::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.entries.get(key) {
            Some(Value::Bool(v)) => Some(*v),
            _ => None,
        }
    }

    /// All keys (sorted; useful for validating unknown options).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Top-level experiment configuration (defaults mirror the paper §III/IV).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Iterations per measurement (paper: 1e5).
    pub iterations: u64,
    /// Warmup iterations excluded from stats.
    pub warmup: u64,
    /// Kronecker scale (paper: 5 → 32 vertices).
    pub scale: u32,
    /// Kronecker edge factor (GAP default 16 reproduces the paper's
    /// 157-edge input; see `graph::kronecker`).
    pub edge_factor: u32,
    /// Generator seed (default reproduces the paper's 157 edges).
    pub seed: u64,
    /// Measurement mode: "sim" (default; deterministic) or "wallclock".
    pub mode: String,
    /// Output directory for figure data files.
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            iterations: 100_000,
            warmup: 1_000,
            scale: 5,
            edge_factor: crate::graph::kronecker::PAPER_EDGE_FACTOR,
            seed: crate::graph::kronecker::PAPER_SEED,
            mode: "sim".into(),
            out_dir: "results".into(),
        }
    }
}

impl ExperimentConfig {
    /// Overlay values from a raw config (section `[experiment]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        ExperimentConfig {
            iterations: raw
                .get_int("experiment.iterations")
                .map(|v| v as u64)
                .unwrap_or(d.iterations),
            warmup: raw.get_int("experiment.warmup").map(|v| v as u64).unwrap_or(d.warmup),
            scale: raw.get_int("experiment.scale").map(|v| v as u32).unwrap_or(d.scale),
            edge_factor: raw
                .get_int("experiment.edge_factor")
                .map(|v| v as u32)
                .unwrap_or(d.edge_factor),
            seed: raw.get_int("experiment.seed").map(|v| v as u64).unwrap_or(d.seed),
            mode: raw.get_str("experiment.mode").unwrap_or(&d.mode).to_string(),
            out_dir: raw.get_str("experiment.out_dir").unwrap_or(&d.out_dir).to_string(),
        }
    }
}

/// Sharded-engine configuration (section `[pool]`; defaults mirror
/// `relic::pool`'s).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolSettings {
    /// Shard count; 0 = auto (one shard per detected physical core).
    pub shards: usize,
    /// Pin shard threads to SMT sibling pairs.
    pub pin: bool,
    /// Per-shard bounded admission-channel depth.
    pub channel_capacity: usize,
    /// Maximum requests per batch handed to a shard's coordinator.
    pub max_batch: usize,
    /// How long a parked producer sleeps between dead-shard checks, in
    /// milliseconds (liveness insurance for `submit_or_park`; the
    /// normal wakeup is the consumer's drain notify).
    pub park_timeout_ms: u64,
    /// Maximum queue depth at which a shard is still offered to a
    /// whale request for cross-shard borrowing (0 = truly idle shards
    /// only; read only with `[relic] max_borrow > 0`).
    pub offer_depth: usize,
}

impl Default for PoolSettings {
    fn default() -> Self {
        PoolSettings {
            shards: 0,
            pin: true,
            channel_capacity: 64,
            max_batch: 32,
            park_timeout_ms: 50,
            offer_depth: 0,
        }
    }
}

impl PoolSettings {
    /// Overlay values from a raw config (section `[pool]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        PoolSettings {
            shards: raw.get_int("pool.shards").map(|v| v.max(0) as usize).unwrap_or(d.shards),
            pin: raw.get_bool("pool.pin").unwrap_or(d.pin),
            channel_capacity: raw
                .get_int("pool.channel_capacity")
                .map(|v| v.max(1) as usize)
                .unwrap_or(d.channel_capacity),
            max_batch: raw
                .get_int("pool.max_batch")
                .map(|v| v.max(1) as usize)
                .unwrap_or(d.max_batch),
            park_timeout_ms: raw
                .get_int("pool.park_timeout_ms")
                .map(|v| v.max(1) as u64)
                .unwrap_or(d.park_timeout_ms),
            offer_depth: raw
                .get_int("pool.offer_depth")
                .map(|v| v.max(0) as usize)
                .unwrap_or(d.offer_depth),
        }
    }

    /// The shard count as the pool layer wants it (`None` = auto).
    pub fn shard_count_hint(&self) -> Option<usize> {
        if self.shards == 0 {
            None
        } else {
            Some(self.shards)
        }
    }
}

/// Admission-control configuration (section `[admission]`; defaults
/// mirror [`crate::coordinator::AdmissionConfig`]: admit everything,
/// no service estimate, no measurement, FIFO batches). The serve CLI's
/// `--shed`, `--deadline-ms`, `--service-estimate-us`, `--ema-alpha`
/// and `--edf` flags override these.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionSettings {
    /// Shed policy spelling: `"never"`, `"past-deadline"`,
    /// `"load-factor"` or `"load-factor:0.75"`.
    pub shed: String,
    /// Per-request service-time estimate in microseconds (0 = slack
    /// estimation disabled; only already-expired deadlines shed). With
    /// `ema_alpha > 0` this seeds and floors the measured EMA instead
    /// of being the estimate itself.
    pub service_estimate_us: u64,
    /// EMA weight of the measured per-shard service-time estimator
    /// (`[0, 1]`; 0 = measurement off, the static knob is
    /// authoritative).
    pub ema_alpha: f64,
    /// Serve deadline-carrying requests earliest-deadline-first within
    /// each shard batch (deadline-less requests keep FIFO order among
    /// themselves; false = pure FIFO).
    pub edf: bool,
    /// Default deadline the serve/admission CLI stamps on generated
    /// requests, in milliseconds (0 = no deadline).
    pub deadline_ms: u64,
}

impl Default for AdmissionSettings {
    fn default() -> Self {
        AdmissionSettings {
            shed: "never".into(),
            service_estimate_us: 0,
            ema_alpha: 0.0,
            edf: false,
            deadline_ms: 0,
        }
    }
}

impl AdmissionSettings {
    /// Overlay values from a raw config (section `[admission]`). An
    /// unrecognized shed spelling keeps the default, matching the other
    /// sections' lenient overlay style.
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        AdmissionSettings {
            shed: raw
                .get_str("admission.shed")
                .filter(|s| crate::coordinator::ShedPolicy::parse(s).is_some())
                .unwrap_or(&d.shed)
                .to_string(),
            service_estimate_us: raw
                .get_int("admission.service_estimate_us")
                .map(|v| v.max(0) as u64)
                .unwrap_or(d.service_estimate_us),
            ema_alpha: raw
                .get_float("admission.ema_alpha")
                .map(|v| v.clamp(0.0, 1.0))
                .unwrap_or(d.ema_alpha),
            edf: raw.get_bool("admission.edf").unwrap_or(d.edf),
            deadline_ms: raw
                .get_int("admission.deadline_ms")
                .map(|v| v.max(0) as u64)
                .unwrap_or(d.deadline_ms),
        }
    }

    /// The parsed shed policy (the spelling is validated on overlay, so
    /// this only falls back to `Never` for a hand-built struct).
    pub fn shed_policy(&self) -> crate::coordinator::ShedPolicy {
        crate::coordinator::ShedPolicy::parse(&self.shed).unwrap_or_default()
    }

    /// Materialize as the engine's runtime admission config.
    pub fn to_config(&self) -> crate::coordinator::AdmissionConfig {
        crate::coordinator::AdmissionConfig {
            shed: self.shed_policy(),
            service_estimate_ns: self.service_estimate_us.saturating_mul(1_000),
            ema_alpha: self.ema_alpha.clamp(0.0, 1.0),
            edf: self.edf,
        }
    }

    /// The default request deadline as a duration (`None` when 0).
    pub fn deadline(&self) -> Option<std::time::Duration> {
        if self.deadline_ms == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(self.deadline_ms))
        }
    }
}

/// Shard-watchdog configuration (section `[supervisor]`; defaults
/// mirror [`crate::relic::SupervisorConfig`]: enabled, 200 ms
/// stuck-detection, 3 restarts per shard with 25 ms base backoff).
/// `enabled = false` restores the pre-supervision failure semantics
/// exactly (dead shards are fatal to `Engine::drain`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SupervisorSettings {
    /// Master switch for panic containment + quarantine + respawn.
    pub enabled: bool,
    /// Heartbeat staleness (with pending work) before a shard counts as
    /// stuck, in milliseconds.
    pub stuck_after_ms: u64,
    /// Restart budget per shard; beyond it a dead shard stays
    /// quarantined and the engine degrades around it.
    pub max_restarts: u32,
    /// First respawn backoff in milliseconds; doubles per restart.
    pub backoff_ms: u64,
    /// Cap on concurrent degraded inline executions (0 = auto: one per
    /// shard, i.e. one per physical core the pool discovered).
    pub degraded_max_inflight: usize,
    /// Consecutive healthy watchdog ticks after which a shard earns one
    /// restart credit back (budget decay; 0 = credits never return).
    pub heal_after_ticks: u32,
    /// Policy once a shard's restart budget is exhausted:
    /// `"quarantine"` (default), `"drain_and_exit"`, or `"rebuild"`.
    /// Unknown spellings are rejected by [`SupervisorSettings::validate`]
    /// rather than silently kept — a misread exit policy is exactly the
    /// kind of config drift an HA deployment cannot absorb.
    pub on_budget_exhausted: String,
}

impl Default for SupervisorSettings {
    fn default() -> Self {
        let d = crate::relic::SupervisorConfig::default();
        SupervisorSettings {
            enabled: d.enabled,
            stuck_after_ms: d.stuck_after.as_millis() as u64,
            max_restarts: d.max_restarts,
            backoff_ms: d.backoff_base.as_millis() as u64,
            degraded_max_inflight: d.degraded_max_inflight,
            heal_after_ticks: d.heal_after_ticks,
            on_budget_exhausted: d.on_budget_exhausted.name().to_string(),
        }
    }
}

impl SupervisorSettings {
    /// Overlay values from a raw config (section `[supervisor]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        SupervisorSettings {
            enabled: raw.get_bool("supervisor.enabled").unwrap_or(d.enabled),
            stuck_after_ms: raw
                .get_int("supervisor.stuck_after_ms")
                .map(|v| v.max(1) as u64)
                .unwrap_or(d.stuck_after_ms),
            max_restarts: raw
                .get_int("supervisor.max_restarts")
                .map(|v| v.max(0) as u32)
                .unwrap_or(d.max_restarts),
            backoff_ms: raw
                .get_int("supervisor.backoff_ms")
                .map(|v| v.max(0) as u64)
                .unwrap_or(d.backoff_ms),
            degraded_max_inflight: raw
                .get_int("supervisor.degraded_max_inflight")
                .map(|v| v.max(0) as usize)
                .unwrap_or(d.degraded_max_inflight),
            heal_after_ticks: raw
                .get_int("supervisor.heal_after_ticks")
                .map(|v| v.max(0) as u32)
                .unwrap_or(d.heal_after_ticks),
            on_budget_exhausted: raw
                .get_str("supervisor.on_budget_exhausted")
                .unwrap_or(&d.on_budget_exhausted)
                .to_string(),
        }
    }

    /// Reject combinations that would change failure semantics in a
    /// way the operator almost certainly did not intend.
    pub fn validate(&self) -> Result<(), ValidationError> {
        if self.enabled && self.stuck_after_ms == 0 {
            return Err(ValidationError {
                key: "supervisor.stuck_after_ms".into(),
                reason: "0 would classify every busy shard as stuck instantly; \
                         set it >= 1 or disable the supervisor"
                    .into(),
            });
        }
        if self.enabled && self.backoff_ms == 0 && self.max_restarts > 0 {
            return Err(ValidationError {
                key: "supervisor.backoff_ms".into(),
                reason: "a zero backoff with a nonzero restart budget respawns a \
                         crash-looping shard in a hot loop; set backoff_ms >= 1 \
                         or max_restarts = 0"
                    .into(),
            });
        }
        if crate::relic::BudgetPolicy::parse(&self.on_budget_exhausted).is_none() {
            return Err(ValidationError {
                key: "supervisor.on_budget_exhausted".into(),
                reason: format!(
                    "unknown policy {:?}; expected quarantine | drain_and_exit | rebuild",
                    self.on_budget_exhausted
                ),
            });
        }
        Ok(())
    }

    /// Materialize as the pool's runtime supervisor config.
    pub fn to_config(&self) -> crate::relic::SupervisorConfig {
        crate::relic::SupervisorConfig {
            enabled: self.enabled,
            stuck_after: std::time::Duration::from_millis(self.stuck_after_ms),
            max_restarts: self.max_restarts,
            backoff_base: std::time::Duration::from_millis(self.backoff_ms),
            degraded_max_inflight: self.degraded_max_inflight,
            heal_after_ticks: self.heal_after_ticks,
            on_budget_exhausted: crate::relic::BudgetPolicy::parse(&self.on_budget_exhausted)
                .unwrap_or_default(),
        }
    }
}

/// At-least-once replay configuration (section `[reliability]`;
/// defaults mirror [`crate::coordinator::ReliabilityConfig`]: replay
/// *off*, so the engine stays bit-for-bit the at-most-once engine).
/// See `ARCHITECTURE.md` §High availability for the replay contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReliabilitySettings {
    /// Master switch for retaining accepted requests and re-submitting
    /// ones that come back with a typed failure.
    pub replay: bool,
    /// Replay attempts per request beyond its first execution.
    pub max_attempts: u32,
    /// Backoff before the first replay, in milliseconds; doubles per
    /// attempt and is capped by the request's remaining deadline slack.
    pub backoff_ms: u64,
    /// Comma-separated allow-list of kernels eligible for replay
    /// (empty = every idempotent kernel). Names must be known kernels
    /// whose idempotence contract holds — see
    /// [`crate::coordinator::GraphKernel::idempotent`].
    pub replay_kernels: String,
}

impl Default for ReliabilitySettings {
    fn default() -> Self {
        let d = crate::coordinator::ReliabilityConfig::default();
        ReliabilitySettings {
            replay: d.replay,
            max_attempts: d.max_attempts,
            backoff_ms: d.backoff_base.as_millis() as u64,
            replay_kernels: String::new(),
        }
    }
}

impl ReliabilitySettings {
    /// Overlay values from a raw config (section `[reliability]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        ReliabilitySettings {
            replay: raw.get_bool("reliability.replay").unwrap_or(d.replay),
            max_attempts: raw
                .get_int("reliability.max_attempts")
                .map(|v| v.max(0) as u32)
                .unwrap_or(d.max_attempts),
            backoff_ms: raw
                .get_int("reliability.backoff_ms")
                .map(|v| v.max(0) as u64)
                .unwrap_or(d.backoff_ms),
            replay_kernels: raw
                .get_str("reliability.replay_kernels")
                .unwrap_or(&d.replay_kernels)
                .to_string(),
        }
    }

    /// The allow-list names, trimmed, with empty entries dropped.
    fn kernel_names(&self) -> Vec<&str> {
        self.replay_kernels
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect()
    }

    /// Reject a replay setup that cannot honor the at-least-once
    /// contract: a zero attempt budget (every failure would count as a
    /// give-up without one retry), an unknown kernel name, or a kernel
    /// whose idempotence contract does not hold (replaying it could
    /// produce a different checksum or a visible side effect).
    pub fn validate(&self) -> Result<(), ValidationError> {
        if self.replay && self.max_attempts == 0 {
            return Err(ValidationError {
                key: "reliability.max_attempts".into(),
                reason: "replay = true with a zero attempt budget never replays \
                         anything; set max_attempts >= 1 or replay = false"
                    .into(),
            });
        }
        for name in self.kernel_names() {
            match crate::coordinator::GraphKernel::parse(name) {
                None => {
                    return Err(ValidationError {
                        key: "reliability.replay_kernels".into(),
                        reason: format!(
                            "unknown kernel {name:?}; expected bc | bfs | cc | pr | sssp | tc"
                        ),
                    });
                }
                Some(k) if !k.idempotent() => {
                    return Err(ValidationError {
                        key: "reliability.replay_kernels".into(),
                        reason: format!(
                            "kernel {name:?} is not idempotent; replaying it is unsafe \
                             and it cannot appear in the allow-list"
                        ),
                    });
                }
                Some(_) => {}
            }
        }
        Ok(())
    }

    /// Materialize as the engine's runtime reliability config. Call
    /// [`validate`](Self::validate) first; unknown allow-list names are
    /// dropped here, not diagnosed.
    pub fn to_config(&self) -> crate::coordinator::ReliabilityConfig {
        crate::coordinator::ReliabilityConfig {
            replay: self.replay,
            max_attempts: self.max_attempts,
            backoff_base: std::time::Duration::from_millis(self.backoff_ms),
            replay_kernels: self
                .kernel_names()
                .into_iter()
                .filter_map(crate::coordinator::GraphKernel::parse)
                .collect(),
        }
    }
}

/// Forced execution-plan configuration (section `[plan]`; default: no
/// forced plan, so nothing plan-related exists at runtime). A forced
/// plan pins every native request to one
/// [`ExecutionPlan`](crate::relic::ExecutionPlan) — the ablation /
/// debugging counterpart of the online tuner, and mutually exclusive
/// with it (see [`check_plan_conflict`]).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PlanSettings {
    /// Plan spec in [`ExecutionPlan::parse`](crate::relic::ExecutionPlan::parse)
    /// syntax (`serial`, `pair:dynamic`, `pair:edge-balanced:32`, …).
    /// Empty (the default) forces nothing.
    pub force: String,
}

impl PlanSettings {
    /// Overlay values from a raw config (section `[plan]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        PlanSettings {
            force: raw.get_str("plan.force").unwrap_or("").to_string(),
        }
    }

    /// Reject a spec [`ExecutionPlan::parse`](crate::relic::ExecutionPlan::parse)
    /// does not accept — a silently dropped plan would run an ablation
    /// under the wrong configuration.
    pub fn validate(&self) -> Result<(), ValidationError> {
        if !self.force.is_empty() && crate::relic::ExecutionPlan::parse(&self.force).is_none() {
            return Err(ValidationError {
                key: "plan.force".into(),
                reason: format!(
                    "unrecognized plan spec {:?}; expected serial | \
                     pair:<static|dynamic|edge-balanced>[:<grain>[:<borrow>]]",
                    self.force
                ),
            });
        }
        Ok(())
    }

    /// The forced plan, or `None` when the spec is empty. Call
    /// [`validate`](Self::validate) first; a malformed spec is `None`
    /// here, not diagnosed.
    pub fn to_plan(&self) -> Option<crate::relic::ExecutionPlan> {
        crate::relic::ExecutionPlan::parse(&self.force)
    }
}

/// Online plan-tuner configuration (section `[tuner]`; defaults mirror
/// [`crate::coordinator::TunerConfig`] with the master switch *off*, so
/// the engine stays bit-for-bit the pre-plan engine).
#[derive(Debug, Clone, PartialEq)]
pub struct TunerSettings {
    /// Master switch for building (and feeding) the online tuner.
    pub enabled: bool,
    /// Exploration probability per settle tick, in `[0, 1]`.
    pub epsilon: f64,
    /// Seed of the tuner's deterministic exploration sequence.
    pub seed: u64,
    /// Samples every arm must collect before greedy selection starts.
    pub min_samples: u64,
    /// Seed arm priors from the probe/smtsim offline oracle at engine
    /// construction (the calibration pass).
    pub calibrate: bool,
}

impl Default for TunerSettings {
    fn default() -> Self {
        let d = crate::coordinator::TunerConfig::default();
        TunerSettings {
            enabled: false,
            epsilon: d.epsilon,
            seed: d.seed,
            min_samples: d.min_samples,
            calibrate: d.calibrate,
        }
    }
}

impl TunerSettings {
    /// Overlay values from a raw config (section `[tuner]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        TunerSettings {
            enabled: raw.get_bool("tuner.enabled").unwrap_or(d.enabled),
            epsilon: raw.get_float("tuner.epsilon").unwrap_or(d.epsilon),
            seed: raw.get_int("tuner.seed").map(|v| v.max(0) as u64).unwrap_or(d.seed),
            min_samples: raw
                .get_int("tuner.min_samples")
                .map(|v| v.max(0) as u64)
                .unwrap_or(d.min_samples),
            calibrate: raw.get_bool("tuner.calibrate").unwrap_or(d.calibrate),
        }
    }

    /// Reject a tuner setup that cannot select plans soundly: an
    /// out-of-range exploration probability, or a zero sample quota
    /// (greedy selection over arms that were never required to collect
    /// a sample compares empty means).
    pub fn validate(&self) -> Result<(), ValidationError> {
        if !self.epsilon.is_finite() || !(0.0..=1.0).contains(&self.epsilon) {
            return Err(ValidationError {
                key: "tuner.epsilon".into(),
                reason: format!(
                    "exploration probability must be in [0, 1], got {}",
                    self.epsilon
                ),
            });
        }
        if self.enabled && self.min_samples == 0 {
            return Err(ValidationError {
                key: "tuner.min_samples".into(),
                reason: "every arm needs at least one forced sample before greedy \
                         selection; set min_samples >= 1 or enabled = false"
                    .into(),
            });
        }
        Ok(())
    }

    /// Materialize as the engine's runtime tuner config, or `None` with
    /// the master switch off. Call [`validate`](Self::validate) first.
    pub fn to_config(&self) -> Option<crate::coordinator::TunerConfig> {
        self.enabled.then(|| crate::coordinator::TunerConfig {
            epsilon: self.epsilon,
            seed: self.seed,
            min_samples: self.min_samples,
            calibrate: self.calibrate,
        })
    }
}

/// A forced plan and an enabled tuner are mutually exclusive: the
/// forced plan wins on every request, so the tuner would measure arms
/// it never chose. Rejected rather than silently resolved — an
/// operator asking for both is confused about which one is driving.
pub fn check_plan_conflict(
    tuner: &TunerSettings,
    plan: &PlanSettings,
) -> Result<(), ValidationError> {
    if tuner.enabled && !plan.force.is_empty() {
        return Err(ValidationError {
            key: "tuner.enabled".into(),
            reason: format!(
                "a forced plan ({:?}) pins every request; the tuner would never \
                 act — drop plan.force / --plan or set enabled = false",
                plan.force
            ),
        });
    }
    Ok(())
}

/// Streaming-pipeline configuration (section `[stream]`; defaults
/// mirror [`crate::coordinator::StreamConfig`]). Everything defaults to
/// *off*: with `enabled = false` the serving path never constructs a
/// pipeline and the engine is response-for-response identical to the
/// non-streaming engine (the degeneracy ladder's newest rung).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamSettings {
    /// Master switch for the parse → analytics → emit pipeline.
    pub enabled: bool,
    /// Stream graph size: `1 << scale` vertices.
    pub scale: u32,
    /// Edges per delta batch.
    pub batch: usize,
    /// Batches per stream run.
    pub batches: usize,
    /// SPSC stage-link capacity (rounded up to a power of two).
    pub queue_capacity: usize,
    /// Rebuild-from-scratch cadence in batches (0 = never); the
    /// bit-identical escape hatch.
    pub recompute_interval: usize,
    /// BFS source vertex (must be `< 1 << scale`).
    pub source: u32,
    /// Edge-stream generator seed.
    pub seed: u64,
    /// Pin the stages to an SMT sibling pair when one is available.
    pub pin: bool,
}

impl Default for StreamSettings {
    fn default() -> Self {
        let d = crate::coordinator::StreamConfig::default();
        StreamSettings {
            enabled: d.enabled,
            scale: d.scale,
            batch: d.batch,
            batches: d.batches,
            queue_capacity: d.queue_capacity,
            recompute_interval: d.recompute_interval,
            source: d.source,
            seed: d.seed,
            pin: d.pin,
        }
    }
}

impl StreamSettings {
    /// Overlay values from a raw config (section `[stream]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        StreamSettings {
            enabled: raw.get_bool("stream.enabled").unwrap_or(d.enabled),
            scale: raw.get_int("stream.scale").map(|v| v.max(0) as u32).unwrap_or(d.scale),
            batch: raw.get_int("stream.batch").map(|v| v.max(0) as usize).unwrap_or(d.batch),
            batches: raw
                .get_int("stream.batches")
                .map(|v| v.max(0) as usize)
                .unwrap_or(d.batches),
            queue_capacity: raw
                .get_int("stream.queue_capacity")
                .map(|v| v.max(0) as usize)
                .unwrap_or(d.queue_capacity),
            recompute_interval: raw
                .get_int("stream.recompute_interval")
                .map(|v| v.max(0) as usize)
                .unwrap_or(d.recompute_interval),
            source: raw.get_int("stream.source").map(|v| v.max(0) as u32).unwrap_or(d.source),
            seed: raw.get_int("stream.seed").map(|v| v.max(0) as u64).unwrap_or(d.seed),
            pin: raw.get_bool("stream.pin").unwrap_or(d.pin),
        }
    }

    /// Reject a stream setup that cannot run: a degenerate graph or
    /// batch shape, a source outside the vertex range, or a scale whose
    /// memoized PageRank trajectory (`MAX_ITERS × 2^scale` doubles)
    /// would not fit a sane memory budget.
    pub fn validate(&self) -> Result<(), ValidationError> {
        if self.scale == 0 || self.scale > 20 {
            return Err(ValidationError {
                key: "stream.scale".into(),
                reason: format!(
                    "scale must be in [1, 20] (2^scale vertices; the delta-PageRank \
                     trajectory memoizes 20 score vectors), got {}",
                    self.scale
                ),
            });
        }
        if self.batch == 0 {
            return Err(ValidationError {
                key: "stream.batch".into(),
                reason: "delta batches need at least one edge".into(),
            });
        }
        if self.batches == 0 {
            return Err(ValidationError {
                key: "stream.batches".into(),
                reason: "a stream run needs at least one batch".into(),
            });
        }
        if self.queue_capacity < 2 {
            return Err(ValidationError {
                key: "stream.queue_capacity".into(),
                reason: format!(
                    "stage links need capacity >= 2 (got {}); a 1-slot ring cannot \
                     overlap producer and consumer",
                    self.queue_capacity
                ),
            });
        }
        if u64::from(self.source) >= (1u64 << self.scale) {
            return Err(ValidationError {
                key: "stream.source".into(),
                reason: format!(
                    "BFS source {} is outside the vertex range 0..{}",
                    self.source,
                    1u64 << self.scale
                ),
            });
        }
        Ok(())
    }

    /// Materialize as the pipeline's runtime config. Call
    /// [`validate`](Self::validate) first.
    pub fn to_config(&self) -> crate::coordinator::StreamConfig {
        crate::coordinator::StreamConfig {
            enabled: self.enabled,
            scale: self.scale,
            batch: self.batch,
            batches: self.batches,
            queue_capacity: self.queue_capacity,
            recompute_interval: self.recompute_interval,
            source: self.source,
            seed: self.seed,
            pin: self.pin,
        }
    }
}

/// Deterministic fault-injection configuration (section `[fault]`;
/// everything defaults to *off* and [`FaultSettings::plan`] returns
/// `None` then, so the compiled-in hooks cost one `Option` branch).
/// `nth` counters are 1-based ("fire on the nth matching event"); a
/// shard index of -1 (the default) disables that injection. This is a
/// chaos-testing/repro tool — see `repro faults` and the
/// `tests/fault_tolerance.rs` suite.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSettings {
    /// Kernel artifact name whose nth native execution panics
    /// (empty = off).
    pub panic_kernel: String,
    /// Which matching execution panics (1-based).
    pub panic_nth: u64,
    /// Shard whose nth batch stalls (-1 = off).
    pub stall_shard: i64,
    pub stall_nth: u64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Shard whose nth response is dropped (-1 = off).
    pub drop_shard: i64,
    pub drop_nth: u64,
    /// Shard whose thread exits on its nth batch (-1 = off). The batch
    /// is requeued first, so no request is lost — only the thread.
    pub kill_shard: i64,
    pub kill_nth: u64,
}

impl Default for FaultSettings {
    fn default() -> Self {
        FaultSettings {
            panic_kernel: String::new(),
            panic_nth: 1,
            stall_shard: -1,
            stall_nth: 1,
            stall_ms: 0,
            drop_shard: -1,
            drop_nth: 1,
            kill_shard: -1,
            kill_nth: 1,
        }
    }
}

impl FaultSettings {
    /// Overlay values from a raw config (section `[fault]`).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        let nth = |key: &str, dflt: u64| raw.get_int(key).map(|v| v.max(1) as u64).unwrap_or(dflt);
        let shard = |key: &str, dflt: i64| raw.get_int(key).map(|v| v.max(-1)).unwrap_or(dflt);
        FaultSettings {
            panic_kernel: raw
                .get_str("fault.panic_kernel")
                .unwrap_or(&d.panic_kernel)
                .to_string(),
            panic_nth: nth("fault.panic_nth", d.panic_nth),
            stall_shard: shard("fault.stall_shard", d.stall_shard),
            stall_nth: nth("fault.stall_nth", d.stall_nth),
            stall_ms: raw.get_int("fault.stall_ms").map(|v| v.max(0) as u64).unwrap_or(d.stall_ms),
            drop_shard: shard("fault.drop_shard", d.drop_shard),
            drop_nth: nth("fault.drop_nth", d.drop_nth),
            kill_shard: shard("fault.kill_shard", d.kill_shard),
            kill_nth: nth("fault.kill_nth", d.kill_nth),
        }
    }

    /// True when no injection is armed.
    pub fn is_empty(&self) -> bool {
        self.panic_kernel.is_empty()
            && self.stall_shard < 0
            && self.drop_shard < 0
            && self.kill_shard < 0
    }

    /// Materialize as the runtime fault plan (`None` when nothing is
    /// armed — the zero-cost default).
    pub fn plan(&self) -> Option<std::sync::Arc<crate::relic::FaultPlan>> {
        if self.is_empty() {
            return None;
        }
        let mut plan = crate::relic::FaultPlan::new();
        if !self.panic_kernel.is_empty() {
            plan = plan.with_panic_on(&self.panic_kernel, self.panic_nth);
        }
        if self.stall_shard >= 0 {
            plan = plan.with_stall(
                self.stall_shard as usize,
                self.stall_nth,
                std::time::Duration::from_millis(self.stall_ms),
            );
        }
        if self.drop_shard >= 0 {
            plan = plan.with_drop_response(self.drop_shard as usize, self.drop_nth);
        }
        if self.kill_shard >= 0 {
            plan = plan.with_kill(self.kill_shard as usize, self.kill_nth);
        }
        Some(std::sync::Arc::new(plan))
    }
}

/// Fork-join runtime configuration (section `[relic]`; defaults mirror
/// [`crate::relic::RelicConfig`]). Pinning stays a CLI/topology concern,
/// so only the portable knobs live here.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RelicSettings {
    /// SPSC queue capacity (paper: 128).
    pub queue_capacity: usize,
    /// Default chunk-assignment schedule for `Par::Relic` loops:
    /// `"static"`, `"dynamic"` or `"edge-balanced"`.
    pub schedule: crate::relic::Schedule,
    /// Maximum idle sibling shards one whale request may borrow for its
    /// parallel loops (0 = cross-shard borrowing off — the engine
    /// builds no lease broker at all).
    pub max_borrow: usize,
}

impl Default for RelicSettings {
    fn default() -> Self {
        RelicSettings {
            queue_capacity: crate::relic::DEFAULT_QUEUE_CAPACITY,
            schedule: crate::relic::Schedule::Static,
            max_borrow: 0,
        }
    }
}

impl RelicSettings {
    /// Overlay values from a raw config (section `[relic]`). Degenerate
    /// values are clamped; an unrecognized schedule name keeps the
    /// default (matching the other sections' lenient overlay style).
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        RelicSettings {
            queue_capacity: raw
                .get_int("relic.queue_capacity")
                .map(|v| v.max(1) as usize)
                .unwrap_or(d.queue_capacity),
            schedule: raw
                .get_str("relic.schedule")
                .and_then(crate::relic::Schedule::parse)
                .unwrap_or(d.schedule),
            max_borrow: raw
                .get_int("relic.max_borrow")
                .map(|v| v.max(0) as usize)
                .unwrap_or(d.max_borrow),
        }
    }

    /// Materialize as a runtime config (CPU pinning left to the caller).
    pub fn to_relic_config(&self) -> crate::relic::RelicConfig {
        crate::relic::RelicConfig {
            queue_capacity: self.queue_capacity,
            schedule: self.schedule,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = RawConfig::parse(
            r#"
            # comment
            top = 1
            [experiment]
            iterations = 5000   # inline comment
            mode = "wallclock"
            ratio = 2.5
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.get_int("top"), Some(1));
        assert_eq!(cfg.get_int("experiment.iterations"), Some(5000));
        assert_eq!(cfg.get_str("experiment.mode"), Some("wallclock"));
        assert_eq!(cfg.get_float("experiment.ratio"), Some(2.5));
        assert_eq!(cfg.get_bool("experiment.enabled"), Some(true));
    }

    #[test]
    fn error_carries_line_number() {
        let err = RawConfig::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn experiment_defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.iterations, 100_000);
        assert_eq!(c.scale, 5);
        assert_eq!(c.edge_factor, 16);
    }

    #[test]
    fn overlay_overrides_defaults_only_where_present() {
        let raw = RawConfig::parse("[experiment]\niterations = 10\n").unwrap();
        let c = ExperimentConfig::from_raw(&raw);
        assert_eq!(c.iterations, 10);
        assert_eq!(c.scale, 5); // default preserved
    }

    #[test]
    fn pool_settings_overlay_and_hint() {
        let d = PoolSettings::default();
        assert_eq!(d.shard_count_hint(), None, "0 means auto");
        let raw = RawConfig::parse(
            "[pool]\nshards = 4\npin = false\nchannel_capacity = 8\nmax_batch = 2\n\
             park_timeout_ms = 10\noffer_depth = 2\n",
        )
        .unwrap();
        let s = PoolSettings::from_raw(&raw);
        assert_eq!(
            s,
            PoolSettings {
                shards: 4,
                pin: false,
                channel_capacity: 8,
                max_batch: 2,
                park_timeout_ms: 10,
                offer_depth: 2,
            }
        );
        assert_eq!(s.shard_count_hint(), Some(4));
        // Partial overlay keeps defaults; degenerate values are clamped.
        let raw = RawConfig::parse("[pool]\nchannel_capacity = 0\npark_timeout_ms = 0\n").unwrap();
        let s = PoolSettings::from_raw(&raw);
        assert_eq!(s.shards, 0);
        assert!(s.pin);
        assert_eq!(s.channel_capacity, 1);
        assert_eq!(s.max_batch, 32);
        assert_eq!(s.park_timeout_ms, 1, "a zero park timeout would spin");
        assert_eq!(s.offer_depth, 0, "whales borrow truly idle shards only by default");
    }

    #[test]
    fn supervisor_settings_overlay_and_materialize() {
        let d = SupervisorSettings::default();
        assert!(d.enabled, "supervision is on by default");
        assert_eq!(d.stuck_after_ms, 200);
        assert_eq!(d.max_restarts, 3);
        assert_eq!(d.backoff_ms, 25);
        assert_eq!(d.degraded_max_inflight, 0, "0 = one inline permit per shard");
        let raw = RawConfig::parse(
            "[supervisor]\nenabled = false\nstuck_after_ms = 50\nmax_restarts = 0\n\
             backoff_ms = 5\ndegraded_max_inflight = 3\n",
        )
        .unwrap();
        let s = SupervisorSettings::from_raw(&raw);
        assert!(!s.enabled);
        let c = s.to_config();
        assert!(!c.enabled);
        assert_eq!(c.stuck_after, std::time::Duration::from_millis(50));
        assert_eq!(c.max_restarts, 0, "a zero budget (quarantine only) is legal");
        assert_eq!(c.backoff_base, std::time::Duration::from_millis(5));
        assert_eq!(c.degraded_max_inflight, 3);
        // Partial overlay keeps defaults elsewhere.
        let raw = RawConfig::parse("[supervisor]\nmax_restarts = 9\n").unwrap();
        let s = SupervisorSettings::from_raw(&raw);
        assert!(s.enabled);
        assert_eq!(s.max_restarts, 9);
        assert_eq!(s.stuck_after_ms, 200);
        // HA knobs: defaults mirror the runtime config, overlays stick.
        assert_eq!(s.heal_after_ticks, 32, "budget decay on by default");
        assert_eq!(s.on_budget_exhausted, "quarantine");
        let raw = RawConfig::parse(
            "[supervisor]\nheal_after_ticks = 0\non_budget_exhausted = \"rebuild\"\n",
        )
        .unwrap();
        let s = SupervisorSettings::from_raw(&raw);
        assert_eq!(s.heal_after_ticks, 0);
        let c = s.to_config();
        assert_eq!(c.heal_after_ticks, 0);
        assert_eq!(c.on_budget_exhausted, crate::relic::BudgetPolicy::Rebuild);
    }

    #[test]
    fn supervisor_validation_rejects_unsafe_combinations() {
        assert!(SupervisorSettings::default().validate().is_ok(), "defaults are valid");
        let mut s = SupervisorSettings {
            stuck_after_ms: 0,
            ..SupervisorSettings::default()
        };
        let err = s.validate().unwrap_err();
        assert_eq!(err.key, "supervisor.stuck_after_ms");
        // The same knobs are fine with supervision off.
        s.enabled = false;
        assert!(s.validate().is_ok());
        let mut s = SupervisorSettings {
            backoff_ms: 0,
            ..SupervisorSettings::default()
        };
        let err = s.validate().unwrap_err();
        assert_eq!(err.key, "supervisor.backoff_ms");
        s.max_restarts = 0;
        assert!(s.validate().is_ok(), "zero backoff is legal without a restart budget");
        let mut s = SupervisorSettings {
            on_budget_exhausted: "explode".into(),
            ..SupervisorSettings::default()
        };
        let err = s.validate().unwrap_err();
        assert_eq!(err.key, "supervisor.on_budget_exhausted");
        assert!(err.to_string().contains("drain_and_exit"), "error names the legal spellings");
        // Both accepted spellings of the exit policy parse.
        s.on_budget_exhausted = "drain_and_exit".into();
        assert!(s.validate().is_ok());
        s.on_budget_exhausted = "drain-and-exit".into();
        assert!(s.validate().is_ok());
    }

    #[test]
    fn reliability_settings_overlay_validate_and_materialize() {
        use crate::coordinator::GraphKernel;
        let d = ReliabilitySettings::default();
        assert!(!d.replay, "replay is opt-in; the default engine is at-most-once");
        assert_eq!(d.max_attempts, 3);
        assert_eq!(d.backoff_ms, 1);
        assert!(d.validate().is_ok());
        let dc = d.to_config();
        assert!(!dc.replay);
        assert!(dc.replay_kernels.is_empty(), "empty list = every idempotent kernel");
        let raw = RawConfig::parse(
            "[reliability]\nreplay = true\nmax_attempts = 5\nbackoff_ms = 2\n\
             replay_kernels = \"bfs, pr\"\n",
        )
        .unwrap();
        let s = ReliabilitySettings::from_raw(&raw);
        assert!(s.replay);
        assert!(s.validate().is_ok());
        let c = s.to_config();
        assert_eq!(c.max_attempts, 5);
        assert_eq!(c.backoff_base, std::time::Duration::from_millis(2));
        assert_eq!(c.replay_kernels, vec![GraphKernel::Bfs, GraphKernel::Pr]);
        assert!(c.replays_kernel(GraphKernel::Bfs));
        assert!(!c.replays_kernel(GraphKernel::Tc), "allow-list restricts replay");
        // Zero attempts with replay on is rejected, not clamped.
        let raw = RawConfig::parse("[reliability]\nreplay = true\nmax_attempts = 0\n").unwrap();
        let err = ReliabilitySettings::from_raw(&raw).validate().unwrap_err();
        assert_eq!(err.key, "reliability.max_attempts");
        // ...but a disabled replay layer tolerates any attempt budget.
        let raw = RawConfig::parse("[reliability]\nmax_attempts = 0\n").unwrap();
        assert!(ReliabilitySettings::from_raw(&raw).validate().is_ok());
        // Unknown kernel names are rejected with the legal spellings.
        let raw =
            RawConfig::parse("[reliability]\nreplay_kernels = \"bfs, warp\"\n").unwrap();
        let err = ReliabilitySettings::from_raw(&raw).validate().unwrap_err();
        assert_eq!(err.key, "reliability.replay_kernels");
        assert!(err.to_string().contains("warp"));
    }

    #[test]
    fn fault_settings_default_off_and_plan_builds() {
        let d = FaultSettings::default();
        assert!(d.is_empty(), "no injection armed by default");
        assert!(d.plan().is_none(), "empty settings cost nothing at runtime");
        let raw = RawConfig::parse(
            "[fault]\npanic_kernel = \"tc\"\npanic_nth = 2\nstall_shard = 1\nstall_ms = 30\n\
             kill_shard = 0\n",
        )
        .unwrap();
        let s = FaultSettings::from_raw(&raw);
        assert!(!s.is_empty());
        assert_eq!(s.panic_kernel, "tc");
        assert_eq!(s.panic_nth, 2);
        assert_eq!(s.stall_shard, 1);
        assert_eq!(s.kill_shard, 0);
        assert_eq!(s.drop_shard, -1, "unset injections stay off");
        let plan = s.plan().expect("armed settings build a plan");
        assert!(!plan.is_empty());
        // The plan carries exactly the armed injections: the second TC
        // execution panics, shard 1's first batch stalls 30 ms, shard
        // 0's first batch kills its thread, nothing drops responses.
        assert!(!plan.should_panic("tc"), "nth = 2: first TC execution passes");
        assert!(plan.should_panic("tc"), "second one fires");
        assert_eq!(plan.stall_duration(1), Some(std::time::Duration::from_millis(30)));
        assert!(plan.should_kill(0));
        assert!(!plan.should_drop_response(0));
        // Degenerate values clamp: nth floors at 1, shards at -1.
        let raw = RawConfig::parse("[fault]\ndrop_shard = -7\ndrop_nth = 0\n").unwrap();
        let s = FaultSettings::from_raw(&raw);
        assert_eq!(s.drop_shard, -1);
        assert_eq!(s.drop_nth, 1);
        assert!(s.is_empty());
    }

    #[test]
    fn admission_settings_overlay_and_materialize() {
        use crate::coordinator::ShedPolicy;
        let d = AdmissionSettings::default();
        assert_eq!(d.shed_policy(), ShedPolicy::Never);
        assert_eq!(d.deadline(), None);
        assert_eq!(d.to_config().service_estimate_ns, 0);
        assert_eq!(d.to_config().ema_alpha, 0.0, "measurement off by default");
        assert!(!d.to_config().edf, "FIFO batches by default");
        let raw = RawConfig::parse(
            "[admission]\nshed = \"load-factor:0.75\"\nservice_estimate_us = 40\n\
             deadline_ms = 250\nema_alpha = 0.25\nedf = true\n",
        )
        .unwrap();
        let s = AdmissionSettings::from_raw(&raw);
        assert_eq!(s.shed_policy(), ShedPolicy::LoadFactor(0.75));
        assert_eq!(s.to_config().service_estimate_ns, 40_000);
        assert_eq!(s.deadline(), Some(std::time::Duration::from_millis(250)));
        assert_eq!(s.to_config().ema_alpha, 0.25);
        assert!(s.to_config().edf);
        // Out-of-range alpha clamps on overlay (and again on
        // materialization, for hand-built structs).
        let raw = RawConfig::parse("[admission]\nema_alpha = 3.5\n").unwrap();
        assert_eq!(AdmissionSettings::from_raw(&raw).ema_alpha, 1.0);
        // An integer alpha parses through the int→float coercion.
        let raw = RawConfig::parse("[admission]\nema_alpha = 1\n").unwrap();
        assert_eq!(AdmissionSettings::from_raw(&raw).ema_alpha, 1.0);
        // Unknown spelling and negative values keep/clamp defaults.
        let raw =
            RawConfig::parse("[admission]\nshed = \"nope\"\ndeadline_ms = -3\n").unwrap();
        let s = AdmissionSettings::from_raw(&raw);
        assert_eq!(s.shed, "never");
        assert_eq!(s.deadline_ms, 0);
        // Partial overlay keeps defaults elsewhere.
        let raw = RawConfig::parse("[admission]\nshed = \"past-deadline\"\n").unwrap();
        let s = AdmissionSettings::from_raw(&raw);
        assert_eq!(s.shed_policy(), ShedPolicy::PastDeadline);
        assert_eq!(s.service_estimate_us, 0);
    }

    #[test]
    fn relic_settings_overlay_and_materialize() {
        use crate::relic::Schedule;
        let d = RelicSettings::default();
        assert_eq!(d.schedule, Schedule::Static);
        assert_eq!(d.queue_capacity, crate::relic::DEFAULT_QUEUE_CAPACITY);
        assert_eq!(d.max_borrow, 0, "cross-shard borrowing off by default");
        let raw = RawConfig::parse(
            "[relic]\nschedule = \"dynamic\"\nqueue_capacity = 8\nmax_borrow = 2\n",
        )
        .unwrap();
        let s = RelicSettings::from_raw(&raw);
        assert_eq!(s.schedule, Schedule::Dynamic);
        assert_eq!(s.queue_capacity, 8);
        assert_eq!(s.max_borrow, 2);
        let rc = s.to_relic_config();
        assert_eq!(rc.schedule, Schedule::Dynamic);
        assert_eq!(rc.queue_capacity, 8);
        // Unknown schedule name and degenerate capacity keep/clamp.
        let raw = RawConfig::parse("[relic]\nschedule = \"nope\"\nqueue_capacity = 0\n").unwrap();
        let s = RelicSettings::from_raw(&raw);
        assert_eq!(s.schedule, Schedule::Static);
        assert_eq!(s.queue_capacity, 1);
        // Edge-balanced round-trips through its config spelling.
        let raw = RawConfig::parse("[relic]\nschedule = \"edge-balanced\"\n").unwrap();
        assert_eq!(RelicSettings::from_raw(&raw).schedule, Schedule::EdgeBalanced);
    }

    #[test]
    fn int_float_coercion() {
        let raw = RawConfig::parse("x = 3\n").unwrap();
        assert_eq!(raw.get_float("x"), Some(3.0));
        assert_eq!(raw.get_str("x"), None);
    }

    #[test]
    fn plan_settings_parse_validate_and_materialize() {
        use crate::relic::{ExecutionPlan, Schedule};
        // Defaults: force nothing, validate clean.
        let d = PlanSettings::default();
        assert!(d.validate().is_ok());
        assert_eq!(d.to_plan(), None);
        // A real spec round-trips into the plan it names.
        let raw = RawConfig::parse("[plan]\nforce = \"pair:edge-balanced:32\"\n").unwrap();
        let s = PlanSettings::from_raw(&raw);
        assert!(s.validate().is_ok());
        assert_eq!(
            s.to_plan(),
            Some(ExecutionPlan::pair(Schedule::EdgeBalanced).with_grain(32))
        );
        // Junk is rejected with the section.key convention.
        let bad = PlanSettings { force: "pair:sideways".into() };
        let err = bad.validate().unwrap_err();
        assert_eq!(err.key, "plan.force");
        assert!(err.to_string().starts_with("invalid config: plan.force:"));
        assert_eq!(bad.to_plan(), None);
    }

    #[test]
    fn tuner_settings_parse_validate_and_materialize() {
        // Off by default: no runtime config is built at all.
        let d = TunerSettings::default();
        assert!(!d.enabled);
        assert!(d.validate().is_ok());
        assert_eq!(d.to_config(), None);
        // Enabled with overrides materializes them.
        let raw = RawConfig::parse(
            "[tuner]\nenabled = true\nepsilon = 0.25\nseed = 7\nmin_samples = 3\n\
             calibrate = true\n",
        )
        .unwrap();
        let s = TunerSettings::from_raw(&raw);
        assert!(s.validate().is_ok());
        let tc = s.to_config().expect("enabled builds a config");
        assert_eq!(tc.epsilon, 0.25);
        assert_eq!(tc.seed, 7);
        assert_eq!(tc.min_samples, 3);
        assert!(tc.calibrate);
        // Out-of-range epsilon and a zero sample quota are typed errors.
        let bad = TunerSettings { epsilon: 1.5, ..TunerSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "tuner.epsilon");
        let bad = TunerSettings { enabled: true, min_samples: 0, ..TunerSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "tuner.min_samples");
    }

    #[test]
    fn stream_settings_parse_validate_and_materialize() {
        // Off by default, and the defaults validate.
        let d = StreamSettings::default();
        assert!(!d.enabled, "streaming is opt-in");
        assert!(d.validate().is_ok());
        assert_eq!(d.to_config(), crate::coordinator::StreamConfig::default());
        // Enabled with overrides materializes them.
        let raw = RawConfig::parse(
            "[stream]\nenabled = true\nscale = 8\nbatch = 64\nbatches = 16\n\
             queue_capacity = 4\nrecompute_interval = 2\nsource = 5\nseed = 9\npin = false\n",
        )
        .unwrap();
        let s = StreamSettings::from_raw(&raw);
        assert!(s.validate().is_ok());
        let c = s.to_config();
        assert!(c.enabled);
        assert_eq!(c.scale, 8);
        assert_eq!(c.batch, 64);
        assert_eq!(c.batches, 16);
        assert_eq!(c.queue_capacity, 4);
        assert_eq!(c.recompute_interval, 2);
        assert_eq!(c.source, 5);
        assert_eq!(c.seed, 9);
        assert!(!c.pin);
        // Partial overlay keeps defaults.
        let raw = RawConfig::parse("[stream]\nbatch = 7\n").unwrap();
        let s = StreamSettings::from_raw(&raw);
        assert_eq!(s.batch, 7);
        assert_eq!(s.scale, StreamSettings::default().scale);
        // Degenerate shapes are typed errors, not clamps.
        let bad = StreamSettings { scale: 0, ..StreamSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "stream.scale");
        let bad = StreamSettings { scale: 21, ..StreamSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "stream.scale");
        let bad = StreamSettings { batch: 0, ..StreamSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "stream.batch");
        let bad = StreamSettings { batches: 0, ..StreamSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "stream.batches");
        let bad = StreamSettings { queue_capacity: 1, ..StreamSettings::default() };
        assert_eq!(bad.validate().unwrap_err().key, "stream.queue_capacity");
        let bad =
            StreamSettings { scale: 4, source: 16, ..StreamSettings::default() };
        let err = bad.validate().unwrap_err();
        assert_eq!(err.key, "stream.source");
        assert!(err.to_string().contains("0..16"));
    }

    #[test]
    fn forced_plan_and_enabled_tuner_conflict() {
        let tuner = TunerSettings { enabled: true, ..TunerSettings::default() };
        let plan = PlanSettings { force: "serial".into() };
        let err = check_plan_conflict(&tuner, &plan).unwrap_err();
        assert_eq!(err.key, "tuner.enabled");
        // Either alone is fine.
        assert!(check_plan_conflict(&tuner, &PlanSettings::default()).is_ok());
        assert!(check_plan_conflict(&TunerSettings::default(), &plan).is_ok());
    }
}
