//! **The high-availability layer**: at-least-once replay bookkeeping
//! and the machine-readable health surface.
//!
//! PR 6 *contained* failures (typed `Failed` responses, quarantine,
//! respawn); this module closes the loop from "failure counted" to
//! "failure recovered":
//!
//! * [`ReliabilityConfig`] — the opt-in `[reliability]` knobs. With
//!   `replay = false` (the default) the engine never clones a request
//!   and never consults the book: bit-for-bit the at-most-once engine.
//! * [`ReplayBook`] — per-sequence retention of accepted requests so a
//!   request that comes back [`RequestResult::Failed`] can be rebuilt
//!   and re-submitted. Replay is allowed only for kernels whose
//!   [`GraphKernel::idempotent`] contract holds, with bounded attempts,
//!   exponential backoff between attempts, and a deadline-aware budget:
//!   a request whose deadline has already passed is **shed, never
//!   replayed** — retrying cannot un-miss a deadline.
//! * [`HealthReport`] / [`ShardHealthRow`] — the serializable snapshot
//!   behind [`Engine::health`](super::Engine::health), `serve
//!   --health-json`, and the `repro health` self-check, with
//!   liveness/readiness semantics an external orchestrator can poll.
//!
//! # The replay state machine
//!
//! ```text
//!  accepted ──► retained (attempts = 0)
//!                  │ response ok          ──► complete  [replay_successes if attempts > 0]
//!                  │ response Failed:
//!                  │   deadline past      ──► surface Failed  [replay_sheds]
//!                  │   attempts = max     ──► surface Failed  [gave_up]
//!                  │   else               ──► backoff, re-submit same seq  [replays]
//! ```
//!
//! Every request that enters the failed branch resolves exactly once —
//! as a replayed success, a deadline shed, or a give-up — so the
//! engine's `submitted = completed + shed + failed_terminal` balance
//! holds with replay on exactly as it does with replay off; the
//! [`crate::metrics::ReliabilityMetrics`] counters make the resolution
//! auditable (`repro chaos` gates on the books reconciling).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::graph::CsrGraph;
use crate::json::{self, Value};

use super::admission::Deadline;
use super::service::Request;
use super::GraphKernel;

/// Knobs for the opt-in at-least-once replay layer (`[reliability]`).
#[derive(Debug, Clone)]
pub struct ReliabilityConfig {
    /// Master switch. Off (the default) retains nothing and replays
    /// nothing: the at-most-once engine, bit-for-bit.
    pub replay: bool,
    /// Replay attempts per request beyond its first execution. `0`
    /// with `replay = true` is rejected by config validation — it
    /// would count every failure as a give-up without ever retrying.
    pub max_attempts: u32,
    /// Backoff before the first replay of a request; doubles per
    /// attempt, and is always capped by the request's remaining
    /// deadline slack (a deadline-less request waits the full backoff).
    pub backoff_base: Duration,
    /// Restrict replay to these kernels (empty = every kernel whose
    /// [`GraphKernel::idempotent`] contract holds). Config validation
    /// rejects a list naming an unknown or non-idempotent kernel.
    pub replay_kernels: Vec<GraphKernel>,
}

impl Default for ReliabilityConfig {
    fn default() -> Self {
        ReliabilityConfig {
            replay: false,
            max_attempts: 3,
            backoff_base: Duration::from_millis(1),
            replay_kernels: Vec::new(),
        }
    }
}

impl ReliabilityConfig {
    /// Whether a request running `kernel` is eligible for retention and
    /// replay under this config: the master switch is on, the kernel's
    /// idempotence contract holds, and the allow-list (when non-empty)
    /// names it.
    pub fn replays_kernel(&self, kernel: GraphKernel) -> bool {
        self.replay
            && kernel.idempotent()
            && (self.replay_kernels.is_empty() || self.replay_kernels.contains(&kernel))
    }
}

/// What the replay book retains per accepted sequence: enough to
/// rebuild the [`Request`] (which is deliberately not `Clone` — the
/// clone cost here is opt-in) plus the attempt count.
#[derive(Debug)]
struct Retained {
    id: u64,
    kernel: GraphKernel,
    graph: CsrGraph,
    source: u32,
    deadline: Deadline,
    /// Replays already launched for this sequence.
    attempts: u32,
}

/// How the book resolved one failed response.
#[derive(Debug)]
pub enum ReplayVerdict {
    /// Re-submit this rebuilt request under the same sequence number
    /// after waiting `backoff` (already capped by deadline slack).
    Replay { request: Request, backoff: Duration },
    /// The deadline passed — surface the typed failure, count a shed.
    Shed,
    /// The attempt budget ran out — surface the typed failure.
    GaveUp,
    /// Nothing retained for this sequence (replay off for it, or a
    /// non-idempotent kernel): surface the failure untouched.
    NotRetained,
}

/// Per-sequence retention for at-least-once replay. Owned by the
/// engine and only touched when `replay = true`.
#[derive(Debug, Default)]
pub struct ReplayBook {
    retained: BTreeMap<u64, Retained>,
}

impl ReplayBook {
    /// Retain an accepted request for possible replay. Non-idempotent
    /// kernels are never retained — their failures always surface
    /// typed, exactly as with replay off.
    pub fn retain(&mut self, seq: u64, req: &Request) {
        if !req.kernel.idempotent() {
            return;
        }
        self.retained.insert(
            seq,
            Retained {
                id: req.id,
                kernel: req.kernel,
                graph: req.graph.clone(),
                source: req.source,
                deadline: req.deadline,
                attempts: 0,
            },
        );
    }

    /// Drop the retention for a sequence that was never actually
    /// queued (a `QueueFull` bounce returned the request to the
    /// caller).
    pub fn forget(&mut self, seq: u64) {
        self.retained.remove(&seq);
    }

    /// A successful response arrived for `seq`: release the retention
    /// and report how many replays it took (`None` when nothing was
    /// retained, `Some(0)` when the first execution succeeded).
    pub fn complete(&mut self, seq: u64) -> Option<u32> {
        self.retained.remove(&seq).map(|r| r.attempts)
    }

    /// A failed response arrived for `seq`: decide its fate. `Replay`
    /// keeps the retention (with the attempt counted) so a repeat
    /// failure is judged against the same budget; every other verdict
    /// releases it.
    pub fn consider(&mut self, seq: u64, config: &ReliabilityConfig, now: Instant) -> ReplayVerdict {
        let Some(entry) = self.retained.get_mut(&seq) else {
            return ReplayVerdict::NotRetained;
        };
        if entry.deadline.is_past(now) {
            self.retained.remove(&seq);
            return ReplayVerdict::Shed;
        }
        if entry.attempts >= config.max_attempts {
            self.retained.remove(&seq);
            return ReplayVerdict::GaveUp;
        }
        // Exponential backoff per attempt, capped by the remaining
        // deadline slack — sleeping past the deadline would turn a
        // recoverable failure into a guaranteed miss.
        let exp = entry.attempts.min(10);
        let mut backoff = config.backoff_base * (1u32 << exp);
        if let Some(slack) = entry.deadline.slack_at(now) {
            backoff = backoff.min(slack);
        }
        entry.attempts += 1;
        ReplayVerdict::Replay {
            request: Request {
                id: entry.id,
                kernel: entry.kernel,
                graph: entry.graph.clone(),
                source: entry.source,
                deadline: entry.deadline,
            },
            backoff,
        }
    }

    /// Retentions currently held (accepted but not yet resolved).
    pub fn len(&self) -> usize {
        self.retained.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.retained.is_empty()
    }

    /// Release every retention (a completed drain owes nothing).
    pub fn clear(&mut self) {
        self.retained.clear();
    }
}

/// One shard's row in the [`HealthReport`].
#[derive(Debug, Clone)]
pub struct ShardHealthRow {
    /// Shard index.
    pub shard: usize,
    /// `healthy | stuck | dead` — what a watchdog pass would decide
    /// right now ([`crate::relic::ShardHealth::name`]).
    pub health: &'static str,
    /// Time since the shard's heartbeat last advanced, in milliseconds.
    pub heartbeat_age_ms: f64,
    /// Requests queued or in processing on the shard.
    pub depth: usize,
    /// Whether routing currently skips the shard.
    pub quarantined: bool,
    /// Duration of the current quarantine, in milliseconds.
    pub quarantined_for_ms: Option<f64>,
    /// Restart credits consumed (budget decay hands them back).
    pub restarts_used: u32,
    /// Restart credits left before `on_budget_exhausted` applies.
    pub restarts_remaining: u32,
    /// A respawn is owed but waiting out its exponential backoff.
    pub backoff_pending: bool,
}

/// Serializable engine health snapshot — the orchestrator-facing
/// surface behind `Engine::health()`, `serve --health-json`, and
/// `repro health`.
///
/// Semantics: **live** means the engine can still answer requests at
/// all — true as long as it exists, because the degraded inline path
/// serves even with every shard down, and false only once a
/// `drain_and_exit` verdict asked the process to terminate. **ready**
/// means the engine should receive new traffic: at least one shard is
/// alive and unquarantined, and no exit has been requested. An
/// orchestrator restarts on `!live` and steers traffic away on
/// `!ready`.
#[derive(Debug, Clone)]
pub struct HealthReport {
    /// The engine can still answer requests (possibly degraded).
    pub live: bool,
    /// The engine should receive new traffic.
    pub ready: bool,
    /// Per-shard status rows.
    pub shards: Vec<ShardHealthRow>,
    /// Shards currently quarantined.
    pub quarantined: usize,
    /// Whether the watchdog is active.
    pub supervised: bool,
    /// Restart budget per shard (0 when unsupervised).
    pub max_restarts: u32,
    /// The budget-exhausted policy name.
    pub on_budget_exhausted: &'static str,
    /// A `drain_and_exit` verdict fired; the process should exit
    /// nonzero after the current drain.
    pub exit_requested: bool,
    /// Degraded-gate size (permits total).
    pub degraded_permits: usize,
    /// Degraded-gate permits in use right now.
    pub degraded_in_use: usize,
    /// Whether at-least-once replay is enabled.
    pub replay: bool,
    /// Requests currently retained for possible replay.
    pub retained_requests: usize,
    /// Fault counters: kernel panics caught.
    pub panics_caught: u64,
    /// Fault counters: shard threads respawned.
    pub shard_restarts: u64,
    /// Fault counters: watchdog quarantine trips.
    pub watchdog_trips: u64,
    /// Fault counters: requests redirected off quarantined shards.
    pub redirected_requests: u64,
    /// Fault counters: requests served inline while degraded.
    pub degraded_requests: u64,
    /// Fault counters: responses synthesized as lost.
    pub responses_lost: u64,
    /// Replay counters: re-submissions launched.
    pub replays: u64,
    /// Replay counters: requests recovered by replay.
    pub replay_successes: u64,
    /// Replay counters: replay candidates shed past their deadline.
    pub replay_sheds: u64,
    /// Replay counters: requests whose replay budget ran out.
    pub gave_up: u64,
    /// Cross-shard lease state: `(served, revoked, chunks_lent)`, when
    /// a broker exists.
    pub leases: Option<(u64, u64, u64)>,
}

impl HealthReport {
    /// Serialize for `serve --health-json` / `repro health` (and any
    /// future wire surface). Key order is stable.
    pub fn to_json(&self) -> String {
        let shards = self
            .shards
            .iter()
            .map(|s| {
                Value::Object(vec![
                    ("shard".into(), Value::Number(s.shard as f64)),
                    ("health".into(), Value::String(s.health.into())),
                    ("heartbeat_age_ms".into(), Value::Number(s.heartbeat_age_ms)),
                    ("depth".into(), Value::Number(s.depth as f64)),
                    ("quarantined".into(), Value::Bool(s.quarantined)),
                    (
                        "quarantined_for_ms".into(),
                        match s.quarantined_for_ms {
                            Some(ms) => Value::Number(ms),
                            None => Value::Null,
                        },
                    ),
                    ("restarts_used".into(), Value::Number(s.restarts_used as f64)),
                    (
                        "restarts_remaining".into(),
                        Value::Number(s.restarts_remaining as f64),
                    ),
                    ("backoff_pending".into(), Value::Bool(s.backoff_pending)),
                ])
            })
            .collect();
        let faults = Value::Object(vec![
            ("panics_caught".into(), Value::Number(self.panics_caught as f64)),
            ("shard_restarts".into(), Value::Number(self.shard_restarts as f64)),
            ("watchdog_trips".into(), Value::Number(self.watchdog_trips as f64)),
            (
                "redirected_requests".into(),
                Value::Number(self.redirected_requests as f64),
            ),
            (
                "degraded_requests".into(),
                Value::Number(self.degraded_requests as f64),
            ),
            ("responses_lost".into(), Value::Number(self.responses_lost as f64)),
        ]);
        let reliability = Value::Object(vec![
            ("replay".into(), Value::Bool(self.replay)),
            (
                "retained_requests".into(),
                Value::Number(self.retained_requests as f64),
            ),
            ("replays".into(), Value::Number(self.replays as f64)),
            (
                "replay_successes".into(),
                Value::Number(self.replay_successes as f64),
            ),
            ("replay_sheds".into(), Value::Number(self.replay_sheds as f64)),
            ("gave_up".into(), Value::Number(self.gave_up as f64)),
        ]);
        let leases = match self.leases {
            Some((served, revoked, chunks_lent)) => Value::Object(vec![
                ("served".into(), Value::Number(served as f64)),
                ("revoked".into(), Value::Number(revoked as f64)),
                ("chunks_lent".into(), Value::Number(chunks_lent as f64)),
            ]),
            None => Value::Null,
        };
        json::to_string(&Value::Object(vec![
            ("live".into(), Value::Bool(self.live)),
            ("ready".into(), Value::Bool(self.ready)),
            ("supervised".into(), Value::Bool(self.supervised)),
            ("quarantined".into(), Value::Number(self.quarantined as f64)),
            ("max_restarts".into(), Value::Number(self.max_restarts as f64)),
            (
                "on_budget_exhausted".into(),
                Value::String(self.on_budget_exhausted.into()),
            ),
            ("exit_requested".into(), Value::Bool(self.exit_requested)),
            (
                "degraded_permits".into(),
                Value::Number(self.degraded_permits as f64),
            ),
            ("degraded_in_use".into(), Value::Number(self.degraded_in_use as f64)),
            ("shards".into(), Value::Array(shards)),
            ("faults".into(), faults),
            ("reliability".into(), reliability),
            ("leases".into(), leases),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::kronecker::paper_graph;

    fn req(id: u64, deadline: Deadline) -> Request {
        Request {
            id,
            kernel: GraphKernel::Bfs,
            graph: paper_graph(),
            source: 0,
            deadline,
        }
    }

    #[test]
    fn replay_book_retains_until_complete() {
        let mut book = ReplayBook::default();
        assert!(book.is_empty());
        book.retain(0, &req(7, Deadline::none()));
        assert_eq!(book.len(), 1);
        assert_eq!(book.complete(0), Some(0));
        assert!(book.is_empty());
        // Completing an unknown sequence is a no-op.
        assert_eq!(book.complete(0), None);
    }

    #[test]
    fn failed_requests_replay_until_the_budget_runs_out() {
        let cfg = ReliabilityConfig {
            replay: true,
            max_attempts: 2,
            backoff_base: Duration::from_millis(1),
        };
        let mut book = ReplayBook::default();
        book.retain(0, &req(7, Deadline::none()));
        let now = Instant::now();
        // First failure: replay with the base backoff.
        match book.consider(0, &cfg, now) {
            ReplayVerdict::Replay { request, backoff } => {
                assert_eq!(request.id, 7);
                assert_eq!(backoff, Duration::from_millis(1));
            }
            other => panic!("expected replay, got {other:?}"),
        }
        // Second failure: backoff doubles.
        match book.consider(0, &cfg, now) {
            ReplayVerdict::Replay { backoff, .. } => {
                assert_eq!(backoff, Duration::from_millis(2));
            }
            other => panic!("expected replay, got {other:?}"),
        }
        // Third failure: budget exhausted; retention released.
        assert!(matches!(book.consider(0, &cfg, now), ReplayVerdict::GaveUp));
        assert!(book.is_empty());
        assert!(matches!(
            book.consider(0, &cfg, now),
            ReplayVerdict::NotRetained
        ));
    }

    #[test]
    fn expired_deadlines_shed_instead_of_replaying() {
        let cfg = ReliabilityConfig::default();
        let mut book = ReplayBook::default();
        let past = Deadline::at(Instant::now() - Duration::from_millis(5));
        book.retain(0, &req(1, past));
        assert!(matches!(
            book.consider(0, &cfg, Instant::now()),
            ReplayVerdict::Shed
        ));
        assert!(book.is_empty());
    }

    #[test]
    fn backoff_is_capped_by_remaining_slack() {
        let cfg = ReliabilityConfig {
            replay: true,
            max_attempts: 1,
            backoff_base: Duration::from_secs(60),
        };
        let mut book = ReplayBook::default();
        let soon = Deadline::within(Duration::from_millis(50));
        book.retain(0, &req(1, soon));
        match book.consider(0, &cfg, Instant::now()) {
            ReplayVerdict::Replay { backoff, .. } => {
                assert!(
                    backoff <= Duration::from_millis(50),
                    "backoff {backoff:?} must not outlast the deadline slack"
                );
            }
            other => panic!("expected replay, got {other:?}"),
        }
    }

    #[test]
    fn queue_full_bounces_forget_their_retention() {
        let mut book = ReplayBook::default();
        book.retain(3, &req(1, Deadline::none()));
        book.forget(3);
        assert!(book.is_empty());
    }

    #[test]
    fn health_report_serializes_stable_keys() {
        let report = HealthReport {
            live: true,
            ready: false,
            shards: vec![ShardHealthRow {
                shard: 0,
                health: "dead",
                heartbeat_age_ms: 12.5,
                depth: 3,
                quarantined: true,
                quarantined_for_ms: Some(40.0),
                restarts_used: 3,
                restarts_remaining: 0,
                backoff_pending: false,
            }],
            quarantined: 1,
            supervised: true,
            max_restarts: 3,
            on_budget_exhausted: "quarantine",
            exit_requested: false,
            degraded_permits: 1,
            degraded_in_use: 0,
            replay: true,
            retained_requests: 2,
            panics_caught: 0,
            shard_restarts: 3,
            watchdog_trips: 1,
            redirected_requests: 4,
            degraded_requests: 0,
            responses_lost: 0,
            replays: 2,
            replay_successes: 1,
            replay_sheds: 0,
            gave_up: 0,
            leases: None,
        };
        let json = report.to_json();
        for key in [
            "\"live\":true",
            "\"ready\":false",
            "\"health\":\"dead\"",
            "\"restarts_remaining\":0",
            "\"on_budget_exhausted\":\"quarantine\"",
            "\"replays\":2",
            "\"leases\":null",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }
}
