//! Admission control for the sharded engine: deadlines, shed policy,
//! and the admission verdict every submit path returns.
//!
//! The engine's front door decides, per request, one of four fates:
//!
//! * **Accepted** — the request is routed to a shard and *will* be
//!   served (accepted requests are never dropped and never reordered
//!   within their shard);
//! * **Degraded** — every shard is quarantined (see
//!   [`crate::relic::Supervisor`]), so the request was served *inline*
//!   on the submitting thread instead of being refused — the engine
//!   keeps answering, just without parallelism. Inline executions are
//!   capped by a counting semaphore (`[supervisor]
//!   degraded_max_inflight`) so a thundering herd of degraded callers
//!   cannot oversubscribe the cores the shards were pinned to;
//! * **QueueFull** — the non-blocking path found the routed shard's
//!   bounded channel full; the request comes back to the caller
//!   untouched, to retry, park, or redirect;
//! * **Shed** — the configured [`ShedPolicy`] decided the request can
//!   no longer meet its [`Deadline`] (or the pool is past its load
//!   threshold), so serving it would waste shard time that on-time
//!   requests need. Shedding happens **at admission, never inside a
//!   shard**: once a request crosses the channel it is part of the
//!   shard's FIFO and dropping it there would break the no-drop /
//!   no-reorder invariant the whole engine is built on — and would
//!   waste the queue slot it already consumed. Every shed is counted
//!   ([`crate::metrics::AdmissionMetrics`]); nothing is dropped
//!   silently.
//!
//! Deadline-less requests are *never* shed under any policy — a
//! deadline is an explicit contract that lateness has zero value, and
//! only requests that opted into that contract are eligible for
//! shedding.

use std::time::{Duration, Instant};

/// When a request stops being worth serving. `Deadline::none()` (the
/// default) means "serve whenever" — such requests are never shed and
/// never count as deadline misses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Deadline(Option<Instant>);

impl Deadline {
    /// No deadline: never shed, never late.
    pub const fn none() -> Self {
        Deadline(None)
    }

    /// Absolute deadline.
    pub const fn at(instant: Instant) -> Self {
        Deadline(Some(instant))
    }

    /// Deadline `budget` from now.
    pub fn within(budget: Duration) -> Self {
        Deadline(Some(Instant::now() + budget))
    }

    /// The absolute instant, if bounded.
    pub fn instant(&self) -> Option<Instant> {
        self.0
    }

    /// True when no deadline was set.
    pub fn is_none(&self) -> bool {
        self.0.is_none()
    }

    /// Remaining slack at `now`: `None` = unbounded, `Some(0)` = past
    /// due.
    pub fn slack_at(&self, now: Instant) -> Option<Duration> {
        self.0.map(|d| d.saturating_duration_since(now))
    }

    /// True when the deadline exists and has passed at `now`.
    pub fn is_past(&self, now: Instant) -> bool {
        matches!(self.0, Some(d) if d <= now)
    }
}

/// What the engine does with requests that cannot (or should not) be
/// served in time. Applies only to requests carrying a [`Deadline`].
///
/// # Examples
///
/// Policies parse from their CLI/config spelling, and the pure
/// [`shed_decision`] applies them:
///
/// ```
/// use relic_smt::coordinator::{shed_decision, Deadline, ShedPolicy, ShedReason};
/// use std::time::{Duration, Instant};
///
/// let policy = ShedPolicy::parse("load-factor:0.8").unwrap();
/// assert_eq!(policy, ShedPolicy::LoadFactor(0.8));
///
/// let now = Instant::now();
/// // An already-expired deadline sheds…
/// assert_eq!(
///     shed_decision(policy, Deadline::at(now), now, Duration::ZERO, 0.0),
///     Some(ShedReason::PastDeadline),
/// );
/// // …an on-time one admits below the load threshold…
/// let live = Deadline::within(Duration::from_secs(60));
/// assert_eq!(shed_decision(policy, live, now, Duration::ZERO, 0.5), None);
/// // …and a deadline-less request is never shed, even overloaded.
/// assert_eq!(
///     shed_decision(policy, Deadline::none(), now, Duration::from_secs(9), 2.0),
///     None,
/// );
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ShedPolicy {
    /// Admit everything; admission degenerates to PR 2's counted
    /// blocking backpressure.
    #[default]
    Never,
    /// Shed requests that can no longer meet their deadline: already
    /// expired at admission, or (when the engine carries a service-time
    /// estimate) with less slack than the estimated wait on the best
    /// shard.
    PastDeadline,
    /// [`PastDeadline`](ShedPolicy::PastDeadline), plus shed *every*
    /// deadlined request while the pool's load factor exceeds the
    /// threshold — overload protection that keeps queueing delay from
    /// pushing the whole deadlined population past due.
    LoadFactor(f32),
}

impl ShedPolicy {
    /// Parse a CLI/config spelling: `never`, `past-deadline`,
    /// `load-factor` (default threshold 0.9) or `load-factor:0.75`.
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "never" => Some(ShedPolicy::Never),
            "past-deadline" => Some(ShedPolicy::PastDeadline),
            "load-factor" => Some(ShedPolicy::LoadFactor(DEFAULT_LOAD_FACTOR)),
            _ => {
                let threshold = s.strip_prefix("load-factor:")?;
                threshold.parse::<f32>().ok().map(ShedPolicy::LoadFactor)
            }
        }
    }

    /// Display name (round-trips through [`parse`](Self::parse)).
    pub fn name(&self) -> String {
        match self {
            ShedPolicy::Never => "never".into(),
            ShedPolicy::PastDeadline => "past-deadline".into(),
            ShedPolicy::LoadFactor(f) => format!("load-factor:{f}"),
        }
    }
}

/// Default overload threshold for `ShedPolicy::LoadFactor`.
pub const DEFAULT_LOAD_FACTOR: f32 = 0.9;

/// Why a request was shed at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The deadline had already expired when the request arrived.
    PastDeadline,
    /// Remaining slack is smaller than the estimated wait on the least
    /// loaded shard — it would miss even if admitted right now.
    SlackExhausted,
    /// Pool load factor above the policy threshold.
    Overload,
}

/// Engine-level admission knobs (the `[admission]` config section and
/// the `serve --shed` / `--service-estimate-us` / `--ema-alpha` /
/// `--edf` flags materialize here).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdmissionConfig {
    /// What to do with requests that cannot meet their deadline.
    pub shed: ShedPolicy,
    /// Per-request service-time estimate in nanoseconds. With
    /// measurement off (`ema_alpha == 0`) this is the estimate, used
    /// verbatim for least-slack routing and the `SlackExhausted` shed
    /// decision; with measurement on it seeds and floors each shard's
    /// per-kernel-class EMA ([`crate::metrics::ServiceEstimator`]).
    /// `0` (the default) disables the static estimate: only
    /// already-expired deadlines shed, which keeps admission decisions
    /// independent of queue depth — and therefore deterministic —
    /// unless the operator opts in.
    pub service_estimate_ns: u64,
    /// EMA weight for the measured service-time estimator, in `[0, 1]`.
    /// `0` (the default) disables measurement entirely — the engine
    /// behaves bit-for-bit like the static-knob PR 4 front door. Values
    /// around `0.1 ..= 0.5` track drift while smoothing noise.
    pub ema_alpha: f64,
    /// Serve deadline-carrying requests earliest-deadline-first within
    /// each drained shard batch ([`edf_order`]). Off (the default), a
    /// batch is processed in FIFO order — bit-for-bit PR 4. Accepted
    /// requests are never dropped either way, and response collection
    /// order (submission order) is unaffected; EDF only changes which
    /// request runs first inside a batch, i.e. who eats the queueing
    /// delay.
    pub edf: bool,
}

/// The earliest-deadline-first processing order of one batch: returns
/// the indices of `deadlines` in the order the requests should run.
///
/// Deadline-carrying requests come first, soonest deadline first (ties
/// keep arrival order); deadline-less requests follow **in their
/// original FIFO order** — in EDF terms their deadline is infinite, and
/// keeping them FIFO among themselves preserves the engine's
/// fairness-among-equals guarantee. Pure in its inputs so the ordering
/// rule is testable without a running engine; with no deadlines present
/// the result is the identity permutation, which is how `edf = true`
/// stays bit-for-bit FIFO on deadline-less traffic.
pub fn edf_order<I>(deadlines: I) -> Vec<usize>
where
    I: IntoIterator<Item = Deadline>,
{
    let ds: Vec<Deadline> = deadlines.into_iter().collect();
    let mut order: Vec<usize> = (0..ds.len()).collect();
    order.sort_by(|&a, &b| match (ds[a].instant(), ds[b].instant()) {
        (Some(x), Some(y)) => x.cmp(&y).then(a.cmp(&b)),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.cmp(&b),
    });
    order
}

/// The verdict of one submit. `QueueFull` and `Shed` hand the request
/// back so the caller can retry, downgrade, or account for it — the
/// engine never consumes a request it did not accept.
#[derive(Debug)]
#[must_use = "an un-accepted verdict carries the request back — dropping it loses the request"]
pub enum Admission {
    /// Queued on `shard`; a response is guaranteed (in submission
    /// order) from the next [`super::Engine::drain`].
    Accepted {
        shard: usize,
        /// True when the parked path had to wait for the shard's
        /// consumer to free channel capacity before the request fit.
        parked: bool,
    },
    /// Non-blocking admission found the routed shard's channel full.
    QueueFull { rejected: super::Request },
    /// The shed policy refused the request (counted, never silent).
    Shed {
        reason: ShedReason,
        request: super::Request,
    },
    /// Every shard was quarantined, so the engine served the request
    /// *inline* on the submitting thread (serial native execution) —
    /// graceful degradation instead of a routing panic. The response is
    /// already complete and comes back from the next
    /// [`super::Engine::drain`] in submission order like any other;
    /// [`crate::metrics::FaultMetrics::degraded_requests`] counts it.
    Degraded,
}

impl Admission {
    /// The shard an accepted request went to (`None` for degraded
    /// inline execution — no shard was involved).
    pub fn shard(&self) -> Option<usize> {
        match self {
            Admission::Accepted { shard, .. } => Some(*shard),
            _ => None,
        }
    }

    /// True when the engine took ownership and a response is guaranteed
    /// from the next drain — queued on a shard, or already served
    /// inline by the degraded path.
    pub fn is_accepted(&self) -> bool {
        matches!(self, Admission::Accepted { .. } | Admission::Degraded)
    }

    /// True when the request was served inline because no shard was
    /// available.
    pub fn is_degraded(&self) -> bool {
        matches!(self, Admission::Degraded)
    }

    pub fn is_queue_full(&self) -> bool {
        matches!(self, Admission::QueueFull { .. })
    }

    pub fn shed_reason(&self) -> Option<ShedReason> {
        match self {
            Admission::Shed { reason, .. } => Some(*reason),
            _ => None,
        }
    }
}

/// The shed decision, pure in its inputs so every submit flavor
/// (blocking, try, parked) applies exactly the same policy:
///
/// * `est_wait` — estimated time until a request admitted *now* to the
///   best shard would complete (queue depth × service estimate,
///   including the request's own service time);
/// * `load_factor` — fraction of total admission capacity in use.
///
/// Returns `None` to admit. `ShedPolicy::Never` and deadline-less
/// requests always admit.
pub fn shed_decision(
    policy: ShedPolicy,
    deadline: Deadline,
    now: Instant,
    est_wait: Duration,
    load_factor: f32,
) -> Option<ShedReason> {
    let slack = match (policy, deadline.slack_at(now)) {
        (ShedPolicy::Never, _) | (_, None) => return None,
        (_, Some(slack)) => slack,
    };
    if slack.is_zero() {
        return Some(ShedReason::PastDeadline);
    }
    if est_wait > slack {
        return Some(ShedReason::SlackExhausted);
    }
    if let ShedPolicy::LoadFactor(threshold) = policy {
        if load_factor > threshold {
            return Some(ShedReason::Overload);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_slack_and_expiry() {
        let now = Instant::now();
        let none = Deadline::none();
        assert!(none.is_none());
        assert_eq!(none.slack_at(now), None);
        assert!(!none.is_past(now));

        let d = Deadline::at(now + Duration::from_millis(5));
        assert_eq!(d.slack_at(now), Some(Duration::from_millis(5)));
        assert!(!d.is_past(now));
        assert!(d.is_past(now + Duration::from_millis(5)));
        assert_eq!(d.slack_at(now + Duration::from_secs(1)), Some(Duration::ZERO));

        let past = Deadline::at(now);
        assert!(past.is_past(now));
        // `within` lands in the future.
        assert!(!Deadline::within(Duration::from_secs(60)).is_past(Instant::now()));
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            ShedPolicy::Never,
            ShedPolicy::PastDeadline,
            ShedPolicy::LoadFactor(0.75),
        ] {
            assert_eq!(ShedPolicy::parse(&p.name()), Some(p), "{}", p.name());
        }
        assert_eq!(
            ShedPolicy::parse("load-factor"),
            Some(ShedPolicy::LoadFactor(DEFAULT_LOAD_FACTOR))
        );
        assert_eq!(ShedPolicy::parse("nope"), None);
        assert_eq!(ShedPolicy::parse("load-factor:x"), None);
        assert_eq!(ShedPolicy::default(), ShedPolicy::Never);
    }

    #[test]
    fn never_and_deadline_less_always_admit() {
        let now = Instant::now();
        let expired = Deadline::at(now);
        // Never admits even an expired deadline under full load.
        assert_eq!(
            shed_decision(ShedPolicy::Never, expired, now, Duration::from_secs(9), 2.0),
            None
        );
        // Deadline-less requests admit under every policy.
        for policy in [
            ShedPolicy::PastDeadline,
            ShedPolicy::LoadFactor(0.0),
        ] {
            assert_eq!(
                shed_decision(policy, Deadline::none(), now, Duration::from_secs(9), 2.0),
                None,
                "{}",
                policy.name()
            );
        }
    }

    #[test]
    fn shed_reasons_in_priority_order() {
        let now = Instant::now();
        let live = Deadline::at(now + Duration::from_millis(10));
        let expired = Deadline::at(now - Duration::from_millis(1));
        let policy = ShedPolicy::LoadFactor(0.5);
        // Expired beats everything.
        assert_eq!(
            shed_decision(policy, expired, now, Duration::ZERO, 0.0),
            Some(ShedReason::PastDeadline)
        );
        // Slack smaller than the estimated wait.
        assert_eq!(
            shed_decision(policy, live, now, Duration::from_millis(11), 0.0),
            Some(ShedReason::SlackExhausted)
        );
        // Slack fits but the pool is overloaded.
        assert_eq!(
            shed_decision(policy, live, now, Duration::from_millis(1), 0.6),
            Some(ShedReason::Overload)
        );
        // Under threshold with slack to spare: admit.
        assert_eq!(shed_decision(policy, live, now, Duration::from_millis(1), 0.4), None);
        // PastDeadline ignores load factor entirely.
        assert_eq!(
            shed_decision(ShedPolicy::PastDeadline, live, now, Duration::from_millis(1), 0.99),
            None
        );
    }

    #[test]
    fn edf_order_sorts_deadlines_and_keeps_deadline_less_fifo() {
        let now = Instant::now();
        let at = |ms: u64| Deadline::at(now + Duration::from_millis(ms));
        // Mixed batch: [loose, none, tight, none, middle].
        let order = edf_order([at(30), Deadline::none(), at(5), Deadline::none(), at(10)]);
        // Deadlined EDF first (tight, middle, loose), then the
        // deadline-less two in arrival order.
        assert_eq!(order, vec![2, 4, 0, 1, 3]);
        // All deadline-less: identity (bit-for-bit FIFO).
        let order = edf_order(std::iter::repeat(Deadline::none()).take(4));
        assert_eq!(order, vec![0, 1, 2, 3]);
        // Equal deadlines keep arrival order (stable ties).
        assert_eq!(edf_order([at(7), at(7), at(7)]), vec![0, 1, 2]);
        // Degenerate batches.
        assert!(edf_order([]).is_empty());
        assert_eq!(edf_order([Deadline::none()]), vec![0]);
    }

    #[test]
    fn edf_order_is_a_permutation_preserving_deadline_less_order() {
        crate::testutil::check(50, |rng| {
            let now = Instant::now();
            let n = (rng.below(12) + 1) as usize;
            let ds: Vec<Deadline> = (0..n)
                .map(|_| {
                    if rng.below(3) == 0 {
                        Deadline::none()
                    } else {
                        Deadline::at(now + Duration::from_micros(rng.below(1_000)))
                    }
                })
                .collect();
            let order = edf_order(ds.clone());
            let mut sorted = order.clone();
            sorted.sort_unstable();
            if sorted != (0..n).collect::<Vec<_>>() {
                return Err(format!("not a permutation: {order:?}"));
            }
            // Deadline-less requests never swap relative to each other.
            let none_positions: Vec<usize> =
                order.iter().copied().filter(|&i| ds[i].is_none()).collect();
            if none_positions.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("deadline-less reordered: {none_positions:?}"));
            }
            // Deadlined requests are non-decreasing in deadline.
            let instants: Vec<_> =
                order.iter().filter_map(|&i| ds[i].instant()).collect();
            if instants.windows(2).any(|w| w[0] > w[1]) {
                return Err("deadlines out of order".into());
            }
            Ok(())
        });
    }

    #[test]
    fn est_wait_equal_to_slack_admits() {
        // The boundary goes to the request: est_wait must *exceed*
        // slack to shed, so a zero estimate (the default) never
        // triggers SlackExhausted.
        let now = Instant::now();
        let d = Deadline::at(now + Duration::from_millis(2));
        assert_eq!(
            shed_decision(ShedPolicy::PastDeadline, d, now, Duration::from_millis(2), 0.0),
            None
        );
    }
}
