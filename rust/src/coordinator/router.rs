//! Request routing: PJRT offload vs native execution, plus the
//! shard-selection rule the sharded [`super::Engine`] admits with.
//!
//! Policy (configurable): kernels whose artifact exists for the
//! request's graph size AND whose dense formulation amortizes the
//! literal-packing cost (n >= `pjrt_min_n`) go to PJRT; everything else
//! runs natively. Fine-grained native requests are additionally marked
//! pairable so the service can co-schedule two of them on the SMT core
//! through Relic.
//!
//! Shard selection ([`pick_shard`]) minimizes *estimated wait* rather
//! than raw queue depth: with a per-request service-time estimate the
//! router can tell the admission layer how long a request admitted now
//! would sit, which is what the least-slack shed decision compares
//! against a deadline's remaining slack. With the estimate disabled
//! (0, the default) it degenerates to exactly PR 2's least-loaded rule.

use super::GraphKernel;
use crate::runtime::Manifest;

/// Execution backend chosen for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled JAX/Pallas kernel via the PJRT client.
    Pjrt,
    /// Native serial kernel on the service threads (Relic-pairable).
    Native,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Smallest graph size worth offloading to PJRT.
    pub pjrt_min_n: usize,
    /// Disable PJRT entirely (no artifacts available).
    pub pjrt_enabled: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { pjrt_min_n: 32, pjrt_enabled: true }
    }
}

/// The routing table: knows which artifacts exist.
pub struct Router {
    cfg: RouterConfig,
    /// (kernel name, n) pairs available as artifacts.
    available: Vec<(String, usize)>,
}

impl Router {
    /// Build from a manifest (pass `None` when artifacts are absent —
    /// everything routes native).
    pub fn new(cfg: RouterConfig, manifest: Option<&Manifest>) -> Self {
        let available = manifest
            .map(|m| m.entries.iter().map(|e| (e.kernel.clone(), e.n)).collect())
            .unwrap_or_default();
        Router { cfg, available }
    }

    /// Choose a backend for `kernel` on an `n`-vertex graph.
    pub fn route(&self, kernel: GraphKernel, n: usize) -> Backend {
        if self.cfg.pjrt_enabled
            && n >= self.cfg.pjrt_min_n
            && self
                .available
                .iter()
                .any(|(k, an)| k == kernel.artifact_name() && *an == n)
        {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }
}

/// Pick the shard a new request should be admitted to, returning the
/// shard index and the estimated wait for a request admitted to it
/// right now. Takes the per-shard depths as an iterator so the hot
/// submit path can feed it straight from the pool's atomics without
/// allocating.
///
/// The estimate is `(depth + 1) × service_estimate_ns`: everything
/// already queued or in processing on the shard, *plus the request's
/// own service time* — "can this deadline still be met" must include
/// actually running the request. With `service_estimate_ns == 0` every
/// estimate is zero and the rule is exactly PR 2's least-loaded pick
/// (ties to the lowest index), so `ShedPolicy::Never` engines route
/// bit-for-bit as before.
///
/// # Panics
/// Panics on an empty `depths` iterator (a pool always has ≥ 1 shard).
pub fn pick_shard<I>(depths: I, service_estimate_ns: u64) -> (usize, std::time::Duration)
where
    I: IntoIterator<Item = usize>,
{
    let mut best = None;
    let mut best_depth = usize::MAX;
    for (i, d) in depths.into_iter().enumerate() {
        if best.is_none() || d < best_depth {
            best = Some(i);
            best_depth = d;
        }
    }
    let best = best.expect("pick_shard needs at least one shard");
    let est_ns = (best_depth as u64).saturating_add(1).saturating_mul(service_estimate_ns);
    (best, std::time::Duration::from_nanos(est_ns))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Entry;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("."),
            entries: vec![
                Entry {
                    kernel: "pagerank".into(),
                    n: 32,
                    file: "pagerank_n32.hlo.txt".into(),
                    inputs: vec![vec![32, 32], vec![32]],
                },
                Entry {
                    kernel: "tc".into(),
                    n: 64,
                    file: "tc_n64.hlo.txt".into(),
                    inputs: vec![vec![64, 64]],
                },
            ],
        }
    }

    #[test]
    fn routes_to_pjrt_when_artifact_exists() {
        let m = manifest();
        let r = Router::new(RouterConfig::default(), Some(&m));
        assert_eq!(r.route(GraphKernel::Pr, 32), Backend::Pjrt);
        assert_eq!(r.route(GraphKernel::Tc, 64), Backend::Pjrt);
        // No artifact at that size.
        assert_eq!(r.route(GraphKernel::Pr, 64), Backend::Native);
        // No artifact for that kernel at all.
        assert_eq!(r.route(GraphKernel::Bfs, 32), Backend::Native);
    }

    #[test]
    fn min_n_gates_offload() {
        let m = manifest();
        let r = Router::new(RouterConfig { pjrt_min_n: 64, pjrt_enabled: true }, Some(&m));
        assert_eq!(r.route(GraphKernel::Pr, 32), Backend::Native);
        assert_eq!(r.route(GraphKernel::Tc, 64), Backend::Pjrt);
    }

    #[test]
    fn pick_shard_is_least_loaded_with_wait_estimate() {
        use std::time::Duration;
        // Ties go low; zero estimate means zero wait (PR 2 rule).
        assert_eq!(pick_shard([0, 0, 0], 0), (0, Duration::ZERO));
        assert_eq!(pick_shard([3, 1, 1], 0), (1, Duration::ZERO));
        // The estimate covers the queue *and* the request itself.
        assert_eq!(pick_shard([3, 2, 5], 1_000), (1, Duration::from_nanos(3_000)));
        assert_eq!(pick_shard([0], 250), (0, Duration::from_nanos(250)));
        // Saturates instead of overflowing on absurd inputs.
        let (_, wait) = pick_shard([usize::MAX], u64::MAX);
        assert_eq!(wait, Duration::from_nanos(u64::MAX));
    }

    #[test]
    fn disabled_or_missing_manifest_routes_native() {
        let m = manifest();
        let off = Router::new(RouterConfig { pjrt_enabled: false, ..Default::default() }, Some(&m));
        assert_eq!(off.route(GraphKernel::Pr, 32), Backend::Native);
        let none = Router::new(RouterConfig::default(), None);
        assert_eq!(none.route(GraphKernel::Pr, 32), Backend::Native);
    }
}
