//! Request routing: PJRT offload vs native execution, plus the
//! shard-selection rule the sharded [`super::Engine`] admits with.
//!
//! Policy (configurable): kernels whose artifact exists for the
//! request's graph size AND whose dense formulation amortizes the
//! literal-packing cost (n >= `pjrt_min_n`) go to PJRT; everything else
//! runs natively. Fine-grained native requests are additionally marked
//! pairable so the service can co-schedule two of them on the SMT core
//! through Relic.
//!
//! Shard selection ([`pick_shard`]) minimizes *estimated wait* rather
//! than raw queue depth: with a per-shard, per-kernel-class
//! service-time estimate (the measured EMA each shard's
//! [`crate::metrics::ServiceEstimator`] maintains, floored by the
//! static `[admission] service_estimate_us` knob) the router can tell
//! the admission layer how long a request admitted now would sit,
//! which is what the least-slack shed decision compares against a
//! deadline's remaining slack. With the estimates disabled (alpha 0,
//! floor 0 — the default) it degenerates to exactly PR 2's
//! least-loaded rule.

use super::GraphKernel;
use crate::runtime::Manifest;

/// Execution backend chosen for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled JAX/Pallas kernel via the PJRT client.
    Pjrt,
    /// Native serial kernel on the service threads (Relic-pairable).
    Native,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Smallest graph size worth offloading to PJRT.
    pub pjrt_min_n: usize,
    /// Disable PJRT entirely (no artifacts available).
    pub pjrt_enabled: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { pjrt_min_n: 32, pjrt_enabled: true }
    }
}

/// The routing table: knows which artifacts exist.
pub struct Router {
    cfg: RouterConfig,
    /// (kernel name, n) pairs available as artifacts.
    available: Vec<(String, usize)>,
}

impl Router {
    /// Build from a manifest (pass `None` when artifacts are absent —
    /// everything routes native).
    pub fn new(cfg: RouterConfig, manifest: Option<&Manifest>) -> Self {
        let available = manifest
            .map(|m| m.entries.iter().map(|e| (e.kernel.clone(), e.n)).collect())
            .unwrap_or_default();
        Router { cfg, available }
    }

    /// Choose a backend for `kernel` on an `n`-vertex graph.
    pub fn route(&self, kernel: GraphKernel, n: usize) -> Backend {
        if self.cfg.pjrt_enabled
            && n >= self.cfg.pjrt_min_n
            && self
                .available
                .iter()
                .any(|(k, an)| k == kernel.artifact_name() && *an == n)
        {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }
}

/// Routing failed: there is no shard a request could be admitted to.
///
/// Reachable only when every shard is quarantined (or the candidate
/// iterator is otherwise empty) — the seam the engine's graceful
/// degradation hangs off: instead of the old empty-iterator panic,
/// [`pick_shard`] hands the admission layer a typed error it can turn
/// into inline serial execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// Every shard was excluded from the candidate set.
    NoShardsAvailable,
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::NoShardsAvailable => write!(f, "no shards available for routing"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Pick the shard a new request should be admitted to, returning the
/// shard index and the estimated wait for a request admitted to it
/// right now. Takes one `(shard, depth, service_estimate_ns)` triple
/// per *candidate* shard as an iterator so the hot submit path can
/// feed it straight from the pool's atomics and the per-shard EMA
/// readouts without allocating — carrying the shard index explicitly
/// lets the engine filter quarantined shards out of the candidate set
/// while the survivors keep their true indices. `service_estimate_ns`
/// is each shard's *measured* per-request estimate for the request's
/// kernel class ([`crate::metrics::ServiceEstimator::estimate_ns`]),
/// which falls back to the static `[admission] service_estimate_us`
/// knob (the EMA's floor) until samples arrive.
///
/// A shard's estimated wait is `(depth + 1) × service_estimate_ns`:
/// everything already queued or in processing on it, *plus the
/// request's own service time* — "can this deadline still be met" must
/// include actually running the request. The pick minimizes that wait;
/// ties break to the smaller depth, then the lowest index. With every
/// estimate 0 (no EMA samples, floor 0 — the default) all waits are
/// zero and the rule is exactly PR 2's least-loaded pick, so
/// `ShedPolicy::Never` engines route bit-for-bit as before; with one
/// uniform static estimate the wait ordering is the depth ordering, so
/// PR 4 routing is also preserved bit-for-bit. Divergence begins only
/// once per-shard EMAs actually differ — the measured case.
///
/// An empty candidate set returns [`RouteError::NoShardsAvailable`]
/// instead of panicking (it used to) — all-shards-quarantined is a
/// recoverable state, not a bug.
pub fn pick_shard<I>(shards: I) -> Result<(usize, std::time::Duration), RouteError>
where
    I: IntoIterator<Item = (usize, usize, u64)>,
{
    // (index, est wait ns, depth) of the best shard so far.
    let mut best: Option<(usize, u64, usize)> = None;
    for (shard, depth, est_ns) in shards {
        let wait = (depth as u64).saturating_add(1).saturating_mul(est_ns);
        let better = match best {
            None => true,
            Some((_, best_wait, best_depth)) => {
                wait < best_wait || (wait == best_wait && depth < best_depth)
            }
        };
        if better {
            best = Some((shard, wait, depth));
        }
    }
    let (shard, wait, _) = best.ok_or(RouteError::NoShardsAvailable)?;
    Ok((shard, std::time::Duration::from_nanos(wait)))
}

/// [`pick_shard`] with lease awareness: each candidate carries a fourth
/// flag — whether the shard currently holds a cross-shard lease (posted
/// or taken, [`crate::relic::LeaseBroker::is_leased`]). Non-leased
/// shards are preferred outright: among them the pick is exactly
/// [`pick_shard`]'s. Only when *every* candidate is leased does the
/// pick fall back to the full set, with the lease folded into the wait
/// estimate as one extra virtual occupant — `(depth + 2) × est_ns` —
/// because a borrowed shard is mid-chunk for a whale and a new request
/// waits out roughly one extra service quantum before the revocation
/// brings the shard home. With every flag false this is bit-for-bit
/// [`pick_shard`] (the `max_borrow = 0` degeneracy).
pub fn pick_shard_leased<I>(shards: I) -> Result<(usize, std::time::Duration), RouteError>
where
    I: IntoIterator<Item = (usize, usize, u64, bool)>,
{
    // Best (index, est wait ns, depth) among non-leased shards, and —
    // in case there are none — among all shards with the lease counted
    // as one extra occupant.
    let mut best_free: Option<(usize, u64, usize)> = None;
    let mut best_any: Option<(usize, u64, usize)> = None;
    for (shard, depth, est_ns, leased) in shards {
        let occupants = (depth as u64).saturating_add(1 + u64::from(leased));
        let wait = occupants.saturating_mul(est_ns);
        let better = |best: &Option<(usize, u64, usize)>| match *best {
            None => true,
            Some((_, best_wait, best_depth)) => {
                wait < best_wait || (wait == best_wait && depth < best_depth)
            }
        };
        if better(&best_any) {
            best_any = Some((shard, wait, depth));
        }
        if !leased && better(&best_free) {
            best_free = Some((shard, wait, depth));
        }
    }
    let (shard, wait, _) = best_free.or(best_any).ok_or(RouteError::NoShardsAvailable)?;
    Ok((shard, std::time::Duration::from_nanos(wait)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Entry;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("."),
            entries: vec![
                Entry {
                    kernel: "pagerank".into(),
                    n: 32,
                    file: "pagerank_n32.hlo.txt".into(),
                    inputs: vec![vec![32, 32], vec![32]],
                },
                Entry {
                    kernel: "tc".into(),
                    n: 64,
                    file: "tc_n64.hlo.txt".into(),
                    inputs: vec![vec![64, 64]],
                },
            ],
        }
    }

    #[test]
    fn routes_to_pjrt_when_artifact_exists() {
        let m = manifest();
        let r = Router::new(RouterConfig::default(), Some(&m));
        assert_eq!(r.route(GraphKernel::Pr, 32), Backend::Pjrt);
        assert_eq!(r.route(GraphKernel::Tc, 64), Backend::Pjrt);
        // No artifact at that size.
        assert_eq!(r.route(GraphKernel::Pr, 64), Backend::Native);
        // No artifact for that kernel at all.
        assert_eq!(r.route(GraphKernel::Bfs, 32), Backend::Native);
    }

    #[test]
    fn min_n_gates_offload() {
        let m = manifest();
        let r = Router::new(RouterConfig { pjrt_min_n: 64, pjrt_enabled: true }, Some(&m));
        assert_eq!(r.route(GraphKernel::Pr, 32), Backend::Native);
        assert_eq!(r.route(GraphKernel::Tc, 64), Backend::Pjrt);
    }

    /// One uniform estimate for every shard (the static-knob shape),
    /// with shard indices 0..n.
    fn uniform(depths: &[usize], est_ns: u64) -> Vec<(usize, usize, u64)> {
        depths.iter().enumerate().map(|(i, &d)| (i, d, est_ns)).collect()
    }

    #[test]
    fn pick_shard_is_least_loaded_with_wait_estimate() {
        use std::time::Duration;
        // Ties go low; zero estimates mean zero wait (PR 2 rule).
        assert_eq!(pick_shard(uniform(&[0, 0, 0], 0)), Ok((0, Duration::ZERO)));
        assert_eq!(pick_shard(uniform(&[3, 1, 1], 0)), Ok((1, Duration::ZERO)));
        // The estimate covers the queue *and* the request itself.
        assert_eq!(
            pick_shard(uniform(&[3, 2, 5], 1_000)),
            Ok((1, Duration::from_nanos(3_000)))
        );
        assert_eq!(pick_shard(uniform(&[0], 250)), Ok((0, Duration::from_nanos(250))));
        // Saturates instead of overflowing on absurd inputs.
        let (_, wait) = pick_shard([(0, usize::MAX, u64::MAX)]).unwrap();
        assert_eq!(wait, Duration::from_nanos(u64::MAX));
    }

    #[test]
    fn pick_shard_measured_estimates_beat_raw_depth() {
        use std::time::Duration;
        // Shard 0 is deeper but measured 10× faster for this class:
        // 4 × 100 ns = 400 ns beats 1 × 10 µs.
        assert_eq!(
            pick_shard([(0, 3, 100), (1, 0, 10_000)]),
            Ok((0, Duration::from_nanos(400)))
        );
        // Equal waits tie-break to the smaller depth, then the index:
        // (1+1)×500 == (0+1)×1000 → shard 1 (depth 0) wins.
        assert_eq!(
            pick_shard([(0, 1, 500), (1, 0, 1_000)]),
            Ok((1, Duration::from_nanos(1_000)))
        );
        // A zero-estimate shard (no samples, no floor) reads as free.
        assert_eq!(pick_shard([(0, 5, 1_000), (1, 9, 0)]), Ok((1, Duration::ZERO)));
    }

    #[test]
    fn pick_shard_keeps_true_indices_and_errors_when_empty() {
        use std::time::Duration;
        // A quarantine-filtered candidate set: shards 0 and 2 are out.
        // The survivors keep their true indices.
        assert_eq!(
            pick_shard([(1, 2, 100), (3, 1, 100)]),
            Ok((3, Duration::from_nanos(200)))
        );
        // Everything quarantined → typed error, not a panic.
        assert_eq!(pick_shard(std::iter::empty()), Err(RouteError::NoShardsAvailable));
        assert_eq!(
            RouteError::NoShardsAvailable.to_string(),
            "no shards available for routing"
        );
    }

    #[test]
    fn pick_shard_leased_all_free_matches_pick_shard() {
        // With every lease flag false the leased variant must be
        // bit-for-bit pick_shard — the max_borrow = 0 degeneracy.
        for cands in [
            vec![(0usize, 3usize, 100u64), (1, 0, 10_000), (2, 1, 500)],
            uniform(&[0, 0, 0], 0),
            uniform(&[3, 2, 5], 1_000),
            vec![(1, 2, 100), (3, 1, 100)],
        ] {
            let flagged: Vec<_> = cands.iter().map(|&(s, d, e)| (s, d, e, false)).collect();
            assert_eq!(pick_shard_leased(flagged), pick_shard(cands));
        }
    }

    #[test]
    fn pick_shard_leased_avoids_whale_serving_shards() {
        use std::time::Duration;
        // Shard 0 is idle but lent to a whale; shard 1 has real queue
        // depth. A small request prefers the non-leased shard outright.
        assert_eq!(
            pick_shard_leased([(0, 0, 1_000, true), (1, 2, 1_000, false)]),
            Ok((1, Duration::from_nanos(3_000)))
        );
        // Everything leased: fall back to the full set with the lease
        // folded in as one extra occupant — (0+2)×1000 beats (1+2)×1000.
        assert_eq!(
            pick_shard_leased([(0, 0, 1_000, true), (1, 1, 1_000, true)]),
            Ok((0, Duration::from_nanos(2_000)))
        );
        // Empty candidate set still errors instead of panicking.
        assert_eq!(pick_shard_leased(std::iter::empty()), Err(RouteError::NoShardsAvailable));
    }

    #[test]
    fn disabled_or_missing_manifest_routes_native() {
        let m = manifest();
        let off = Router::new(RouterConfig { pjrt_enabled: false, ..Default::default() }, Some(&m));
        assert_eq!(off.route(GraphKernel::Pr, 32), Backend::Native);
        let none = Router::new(RouterConfig::default(), None);
        assert_eq!(none.route(GraphKernel::Pr, 32), Backend::Native);
    }
}
