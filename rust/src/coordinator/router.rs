//! Request routing: PJRT offload vs native execution.
//!
//! Policy (configurable): kernels whose artifact exists for the
//! request's graph size AND whose dense formulation amortizes the
//! literal-packing cost (n >= `pjrt_min_n`) go to PJRT; everything else
//! runs natively. Fine-grained native requests are additionally marked
//! pairable so the service can co-schedule two of them on the SMT core
//! through Relic.

use super::GraphKernel;
use crate::runtime::Manifest;

/// Execution backend chosen for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled JAX/Pallas kernel via the PJRT client.
    Pjrt,
    /// Native serial kernel on the service threads (Relic-pairable).
    Native,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Smallest graph size worth offloading to PJRT.
    pub pjrt_min_n: usize,
    /// Disable PJRT entirely (no artifacts available).
    pub pjrt_enabled: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { pjrt_min_n: 32, pjrt_enabled: true }
    }
}

/// The routing table: knows which artifacts exist.
pub struct Router {
    cfg: RouterConfig,
    /// (kernel name, n) pairs available as artifacts.
    available: Vec<(String, usize)>,
}

impl Router {
    /// Build from a manifest (pass `None` when artifacts are absent —
    /// everything routes native).
    pub fn new(cfg: RouterConfig, manifest: Option<&Manifest>) -> Self {
        let available = manifest
            .map(|m| m.entries.iter().map(|e| (e.kernel.clone(), e.n)).collect())
            .unwrap_or_default();
        Router { cfg, available }
    }

    /// Choose a backend for `kernel` on an `n`-vertex graph.
    pub fn route(&self, kernel: GraphKernel, n: usize) -> Backend {
        if self.cfg.pjrt_enabled
            && n >= self.cfg.pjrt_min_n
            && self
                .available
                .iter()
                .any(|(k, an)| k == kernel.artifact_name() && *an == n)
        {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Entry;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("."),
            entries: vec![
                Entry {
                    kernel: "pagerank".into(),
                    n: 32,
                    file: "pagerank_n32.hlo.txt".into(),
                    inputs: vec![vec![32, 32], vec![32]],
                },
                Entry {
                    kernel: "tc".into(),
                    n: 64,
                    file: "tc_n64.hlo.txt".into(),
                    inputs: vec![vec![64, 64]],
                },
            ],
        }
    }

    #[test]
    fn routes_to_pjrt_when_artifact_exists() {
        let m = manifest();
        let r = Router::new(RouterConfig::default(), Some(&m));
        assert_eq!(r.route(GraphKernel::Pr, 32), Backend::Pjrt);
        assert_eq!(r.route(GraphKernel::Tc, 64), Backend::Pjrt);
        // No artifact at that size.
        assert_eq!(r.route(GraphKernel::Pr, 64), Backend::Native);
        // No artifact for that kernel at all.
        assert_eq!(r.route(GraphKernel::Bfs, 32), Backend::Native);
    }

    #[test]
    fn min_n_gates_offload() {
        let m = manifest();
        let r = Router::new(RouterConfig { pjrt_min_n: 64, pjrt_enabled: true }, Some(&m));
        assert_eq!(r.route(GraphKernel::Pr, 32), Backend::Native);
        assert_eq!(r.route(GraphKernel::Tc, 64), Backend::Pjrt);
    }

    #[test]
    fn disabled_or_missing_manifest_routes_native() {
        let m = manifest();
        let off = Router::new(RouterConfig { pjrt_enabled: false, ..Default::default() }, Some(&m));
        assert_eq!(off.route(GraphKernel::Pr, 32), Backend::Native);
        let none = Router::new(RouterConfig::default(), None);
        assert_eq!(none.route(GraphKernel::Pr, 32), Backend::Native);
    }
}
