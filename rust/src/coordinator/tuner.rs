//! Online execution-plan tuner: closes the measurement loop the ROADMAP
//! called "profile-guided adaptive execution".
//!
//! The engine already *measures* per-kernel completion latencies
//! ([`crate::metrics::ServiceEstimator`]); this module makes the
//! measurements *steer*. A [`Tuner`] keeps one statistics cell per
//! (kernel class, graph-shape class); each cell runs epsilon-greedy
//! over the shared candidate lattice of [`ExecutionPlan`]s
//! ([`ExecutionPlan::lattice`]): serial, plus pair-parallel under every
//! schedule at three grain tiers.
//!
//! Division of labor, chosen so the shard hot path stays lock-free:
//! * [`Tuner::plan_for`] — *hot*, called per request from shard
//!   threads: one relaxed atomic load of the cell's current arm.
//! * [`Tuner::record`] — *hot*, called per completion: two relaxed
//!   atomic adds on the sampled arm.
//! * [`Tuner::tick`] — *cold*, called by the engine's drain path at
//!   settle points: re-selects each cell's arm (forced round-robin
//!   until every arm has `min_samples`, then epsilon-greedy on mean
//!   latency). Randomness comes from a seeded LCG, so a fixed seed
//!   yields a fixed decision sequence for a fixed feed — the
//!   repo's determinism discipline extends to the tuner itself.
//!
//! An optional offline **calibration pass** ([`Tuner::calibrate`])
//! revives the dormant probe/smtsim machinery as an oracle: each
//! kernel's calibrated instruction trace ([`crate::bench::Workload`])
//! is co-simulated against itself on the SMT core model
//! ([`crate::smtsim::speedup`]), and the predicted pairing speedup
//! seeds every cell's arms as prior samples — the tuner then starts
//! from the oracle's ranking instead of a cold uniform sweep.
//!
//! Correctness contract: plans change *assignment only* — every arm
//! the tuner explores yields checksums bitwise-equal to serial (see
//! `tests/plan_correctness.rs`), so exploration is never visible in
//! responses, only in latency.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::relic::{ExecutionPlan, ParMode};

use super::GraphKernel;

/// Graph-shape classes: coarse vertex-count buckets. Service time per
/// plan varies with input size (a 32-vertex task amortizes no fork-join
/// overhead; a 100k-vertex one does), so each bucket tunes separately.
pub const SHAPE_CLASSES: usize = 4;

/// The shape class of a graph with `n` vertices.
pub fn shape_class(n: usize) -> usize {
    match n {
        0..=63 => 0,
        64..=511 => 1,
        512..=4095 => 2,
        _ => 3,
    }
}

/// Human-readable name of a shape class (report labels).
pub fn shape_name(class: usize) -> &'static str {
    match class {
        0 => "n<64",
        1 => "n<512",
        2 => "n<4096",
        _ => "n>=4096",
    }
}

/// Tuner policy knobs (see `config::TunerSettings` for the validated
/// config-file surface that produces this).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunerConfig {
    /// Exploration probability per [`Tuner::tick`] once every arm has
    /// `min_samples`.
    pub epsilon: f64,
    /// Seed of the tuner's deterministic LCG.
    pub seed: u64,
    /// Samples every arm must collect before greedy selection starts
    /// (the forced round-robin phase).
    pub min_samples: u64,
    /// Run the smtsim calibration pass at engine construction.
    pub calibrate: bool,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig { epsilon: 0.1, seed: 1, min_samples: 2, calibrate: false }
    }
}

/// Per-arm statistics: sample count and total latency, both relaxed
/// atomics so shard threads record without coordination.
struct Arm {
    count: AtomicU64,
    total_ns: AtomicU64,
}

impl Arm {
    fn new() -> Self {
        Arm { count: AtomicU64::new(0), total_ns: AtomicU64::new(0) }
    }

    fn mean_ns(&self) -> Option<f64> {
        let count = self.count.load(Ordering::Relaxed);
        (count > 0).then(|| self.total_ns.load(Ordering::Relaxed) as f64 / count as f64)
    }
}

/// One (kernel class, shape class) statistics cell.
struct Cell {
    /// Index into the lattice of the arm new requests should use.
    current: AtomicUsize,
    /// Round-robin cursor for the epsilon-exploration branch.
    explore_cursor: AtomicUsize,
    /// Total sample count at the last tick: a cell with no new traffic
    /// keeps its arm and consumes no randomness, so the decision
    /// sequence depends only on the recorded feed, not on how often
    /// the engine settles.
    last_total: AtomicU64,
    arms: Vec<Arm>,
}

impl Cell {
    fn new(arms: usize, default_arm: usize) -> Self {
        Cell {
            current: AtomicUsize::new(default_arm),
            explore_cursor: AtomicUsize::new(0),
            last_total: AtomicU64::new(0),
            arms: (0..arms).map(|_| Arm::new()).collect(),
        }
    }

    fn total(&self) -> u64 {
        self.arms.iter().map(|a| a.count.load(Ordering::Relaxed)).sum()
    }

    /// Best-mean arm among those with samples; `None` on a cold cell.
    fn best_arm(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, arm) in self.arms.iter().enumerate() {
            if let Some(mean) = arm.mean_ns() {
                if best.map(|(_, m)| mean < m).unwrap_or(true) {
                    best = Some((i, mean));
                }
            }
        }
        best.map(|(i, _)| i)
    }
}

/// One row of the resolved-plan table (see [`Tuner::resolved`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ResolvedPlan {
    pub kernel: GraphKernel,
    pub shape: usize,
    pub plan: ExecutionPlan,
    pub samples: u64,
    pub mean_ns: u64,
}

/// The online plan selector. One instance is shared (via `Arc`) by
/// every shard of an engine, so arm statistics aggregate machine-wide.
pub struct Tuner {
    cfg: TunerConfig,
    lattice: Vec<ExecutionPlan>,
    cells: Vec<Cell>,
    /// LCG state; touched only by [`tick`](Self::tick).
    rng: AtomicU64,
    ticks: AtomicU64,
    explorations: AtomicU64,
}

impl Tuner {
    /// Build over [`ExecutionPlan::lattice`]. Every cell starts on the
    /// pre-plan default arm, so a tuner that never ticks assigns
    /// exactly the engine's historical behavior.
    pub fn new(cfg: TunerConfig) -> Self {
        let lattice = ExecutionPlan::lattice();
        let default_arm = lattice
            .iter()
            .position(|p| *p == ExecutionPlan::default())
            .expect("lattice contains the default plan");
        let cells = (0..crate::metrics::SERVICE_CLASSES * SHAPE_CLASSES)
            .map(|_| Cell::new(lattice.len(), default_arm))
            .collect();
        Tuner {
            rng: AtomicU64::new(cfg.seed.wrapping_mul(2).wrapping_add(1)),
            cfg,
            lattice,
            cells,
            ticks: AtomicU64::new(0),
            explorations: AtomicU64::new(0),
        }
    }

    /// The candidate lattice this tuner selects over.
    pub fn lattice(&self) -> &[ExecutionPlan] {
        &self.lattice
    }

    fn cell(&self, kernel: GraphKernel, n: usize) -> &Cell {
        &self.cells[kernel.class() * SHAPE_CLASSES + shape_class(n)]
    }

    /// The plan a request of this (kernel, size) should run under, and
    /// the arm index to pass back to [`record`](Self::record). Hot
    /// path: one relaxed load.
    pub fn plan_for(&self, kernel: GraphKernel, n: usize) -> (usize, ExecutionPlan) {
        let arm = self.cell(kernel, n).current.load(Ordering::Relaxed).min(self.lattice.len() - 1);
        (arm, self.lattice[arm])
    }

    /// Feed one measured completion latency back to the sampled arm.
    /// Hot path: two relaxed adds.
    pub fn record(&self, kernel: GraphKernel, n: usize, arm: usize, latency_ns: u64) {
        if let Some(a) = self.cell(kernel, n).arms.get(arm) {
            a.count.fetch_add(1, Ordering::Relaxed);
            a.total_ns.fetch_add(latency_ns, Ordering::Relaxed);
        }
    }

    /// One uniform draw in `[0, 1)` from the seeded LCG.
    fn next_uniform(&self) -> f64 {
        // MMIX constants; the low bits are weak, so take the top 53.
        let next = self
            .rng
            .load(Ordering::Relaxed)
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.rng.store(next, Ordering::Relaxed);
        (next >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Re-select every cell's arm. Called from the engine's drain path
    /// at settle points (never from shard threads). Cells with no new
    /// samples since the last tick are left untouched and consume no
    /// randomness.
    pub fn tick(&self) {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        for cell in &self.cells {
            let total = cell.total();
            if cell.last_total.swap(total, Ordering::Relaxed) == total {
                continue;
            }
            // Forced exploration: cycle through under-sampled arms
            // (starting from the current one, so it finishes its quota
            // before the cursor moves on) until every arm has
            // `min_samples`.
            let cur = cell.current.load(Ordering::Relaxed).min(self.lattice.len() - 1);
            let k = self.lattice.len();
            let under = (0..k)
                .map(|off| (cur + off) % k)
                .find(|&i| cell.arms[i].count.load(Ordering::Relaxed) < self.cfg.min_samples);
            if let Some(arm) = under {
                cell.current.store(arm, Ordering::Relaxed);
                continue;
            }
            if self.next_uniform() < self.cfg.epsilon {
                self.explorations.fetch_add(1, Ordering::Relaxed);
                let arm = cell.explore_cursor.fetch_add(1, Ordering::Relaxed) % k;
                cell.current.store(arm, Ordering::Relaxed);
            } else if let Some(best) = cell.best_arm() {
                cell.current.store(best, Ordering::Relaxed);
            }
        }
    }

    /// Offline calibration (the revived probe/smtsim oracle): simulate
    /// each kernel's calibrated trace co-running with itself on the SMT
    /// core model and seed every cell's arms with the predicted
    /// serial-vs-pair ratio as `min_samples` prior samples each. The
    /// priors satisfy the forced-exploration quota, so a calibrated
    /// tuner starts greedy on the oracle's ranking and lets real
    /// measurements overrule it. Deterministic: the simulator is a pure
    /// function of the traces and the core model.
    pub fn calibrate(&self) {
        use crate::smtsim::CoreConfig;
        // Only the serial:pair *ratio* matters; the scale cancels out
        // of every mean comparison and real samples soon dominate.
        const PRIOR_NS: f64 = (1u64 << 20) as f64;
        let core = CoreConfig::default();
        let prior_count = self.cfg.min_samples.max(1);
        for kernel in GraphKernel::all() {
            let name = workload_name(kernel);
            let trace = crate::bench::Workload::new(name).trace(0, &core);
            let speed = crate::smtsim::speedup("relic", &trace, &trace, &core).max(0.1);
            for shape in 0..SHAPE_CLASSES {
                let cell = &self.cells[kernel.class() * SHAPE_CLASSES + shape];
                for (i, plan) in self.lattice.iter().enumerate() {
                    let prior = match plan.par_mode {
                        ParMode::Serial => PRIOR_NS,
                        ParMode::Pair => PRIOR_NS / speed,
                    };
                    cell.arms[i].count.fetch_add(prior_count, Ordering::Relaxed);
                    cell.arms[i]
                        .total_ns
                        .fetch_add(prior as u64 * prior_count, Ordering::Relaxed);
                }
                if let Some(best) = cell.best_arm() {
                    cell.current.store(best, Ordering::Relaxed);
                }
            }
        }
    }

    /// The resolved per-(kernel, shape) plan table: current arm, sample
    /// count and mean latency for every cell that has data. Printed by
    /// `Engine::report` when the tuner is on.
    pub fn resolved(&self) -> Vec<ResolvedPlan> {
        let mut rows = Vec::new();
        for kernel in GraphKernel::all() {
            for shape in 0..SHAPE_CLASSES {
                let cell = &self.cells[kernel.class() * SHAPE_CLASSES + shape];
                let samples = cell.total();
                if samples == 0 {
                    continue;
                }
                let arm = cell.current.load(Ordering::Relaxed).min(self.lattice.len() - 1);
                rows.push(ResolvedPlan {
                    kernel,
                    shape,
                    plan: self.lattice[arm],
                    samples,
                    mean_ns: cell.arms[arm].mean_ns().unwrap_or(0.0) as u64,
                });
            }
        }
        rows
    }

    /// One-line activity summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} ticks, {} explorations, epsilon {}, seed {}",
            self.ticks.load(Ordering::Relaxed),
            self.explorations.load(Ordering::Relaxed),
            self.cfg.epsilon,
            self.cfg.seed,
        )
    }
}

/// The [`crate::bench::Workload`] name of a kernel (the bench table
/// spells PageRank "pr", the artifact manifest "pagerank").
fn workload_name(kernel: GraphKernel) -> &'static str {
    match kernel {
        GraphKernel::Bc => "bc",
        GraphKernel::Bfs => "bfs",
        GraphKernel::Cc => "cc",
        GraphKernel::Pr => "pr",
        GraphKernel::Sssp => "sssp",
        GraphKernel::Tc => "tc",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive one cell with a synthetic latency feed: the planted arm
    /// measures `fast` ns, every other arm `slow` ns.
    fn drive(tuner: &Tuner, kernel: GraphKernel, n: usize, planted: usize, rounds: usize) {
        for _ in 0..rounds {
            let (arm, _) = tuner.plan_for(kernel, n);
            tuner.record(kernel, n, arm, if arm == planted { 100 } else { 1_000 });
            tuner.tick();
        }
    }

    #[test]
    fn fresh_tuner_assigns_the_preplan_default() {
        let tuner = Tuner::new(TunerConfig::default());
        for kernel in GraphKernel::all() {
            for n in [32, 100, 1000, 10_000] {
                let (_, plan) = tuner.plan_for(kernel, n);
                assert_eq!(plan, ExecutionPlan::default(), "{kernel:?} n={n}");
            }
        }
    }

    #[test]
    fn converges_to_the_planted_best_arm() {
        // Pure greed after the forced sweep (epsilon 0): the tuner must
        // land on the planted arm and stay there.
        let cfg = TunerConfig { epsilon: 0.0, min_samples: 2, ..TunerConfig::default() };
        let tuner = Tuner::new(cfg);
        let planted = 7; // an arbitrary non-default arm
        drive(&tuner, GraphKernel::Tc, 32, planted, 3 * tuner.lattice().len());
        for _ in 0..10 {
            let (arm, _) = tuner.plan_for(GraphKernel::Tc, 32);
            assert_eq!(arm, planted);
            tuner.record(GraphKernel::Tc, 32, arm, 100);
            tuner.tick();
        }
        // Other cells never saw traffic and still hold the default.
        let (_, plan) = tuner.plan_for(GraphKernel::Tc, 100_000);
        assert_eq!(plan, ExecutionPlan::default());
    }

    #[test]
    fn fixed_seed_selection_sequences_are_deterministic() {
        let cfg = TunerConfig { epsilon: 0.3, seed: 42, ..TunerConfig::default() };
        let run = || {
            let tuner = Tuner::new(cfg);
            let mut arms = Vec::new();
            for round in 0..200 {
                let (arm, _) = tuner.plan_for(GraphKernel::Bfs, 512);
                // Latency depends only on (arm, round): a fixed feed.
                tuner.record(GraphKernel::Bfs, 512, arm, 500 + (arm as u64 * 37 + round) % 100);
                tuner.tick();
                arms.push(arm);
            }
            arms
        };
        assert_eq!(run(), run(), "same seed + same feed => same plan sequence");
    }

    #[test]
    fn cells_without_new_traffic_keep_their_arm_and_consume_no_randomness() {
        let cfg = TunerConfig { epsilon: 1.0, min_samples: 1, ..TunerConfig::default() };
        let tuner = Tuner::new(cfg);
        drive(&tuner, GraphKernel::Cc, 32, 0, 2 * tuner.lattice().len());
        let (arm_before, _) = tuner.plan_for(GraphKernel::Cc, 32);
        // Idle ticks: no cell saw new samples, so nothing may move.
        for _ in 0..50 {
            tuner.tick();
        }
        let (arm_after, _) = tuner.plan_for(GraphKernel::Cc, 32);
        assert_eq!(arm_before, arm_after);
    }

    #[test]
    fn calibration_seeds_every_cell_and_prefers_pair_when_the_sim_does() {
        let tuner = Tuner::new(TunerConfig::default());
        tuner.calibrate();
        let rows = tuner.resolved();
        assert_eq!(
            rows.len(),
            crate::metrics::SERVICE_CLASSES * SHAPE_CLASSES,
            "every cell carries prior samples"
        );
        // The seeded mode must agree with the oracle: pair wherever
        // the simulator predicts a pairing speedup, serial otherwise.
        let core = crate::smtsim::CoreConfig::default();
        for row in &rows {
            let trace =
                crate::bench::Workload::new(workload_name(row.kernel)).trace(0, &core);
            let sp = crate::smtsim::speedup("relic", &trace, &trace, &core);
            let want = if sp > 1.0 { ParMode::Pair } else { ParMode::Serial };
            assert_eq!(
                row.plan.par_mode,
                want,
                "{:?}/{} seeded against the oracle (speedup {sp:.3})",
                row.kernel,
                shape_name(row.shape)
            );
        }
    }

    #[test]
    fn record_out_of_range_arm_is_ignored() {
        let tuner = Tuner::new(TunerConfig::default());
        tuner.record(GraphKernel::Pr, 32, 10_000, 999);
        assert!(tuner.resolved().is_empty());
    }
}
