//! Streaming pipeline: parse → analytics → emit over SPSC stage links.
//!
//! The paper's argument for near-zero-overhead tasking is strongest when
//! each unit of work is microseconds — exactly the regime of streaming
//! edge updates. This module composes three pipeline stages, linked by
//! the same lock-free [`SpscQueue`] the Relic runtime uses for its own
//! task handoff (FastFlow-style stage composition, PAPERS.md):
//!
//! ```text
//!   driver ──q₀──▶ parse ──q₁──▶ analytics ──q₂──▶ emit
//!                 (JSON →        (DeltaCsr +        (records →
//!                  edge batch)    incremental        JSON lines,
//!                                 kernels)           order check)
//! ```
//!
//! JSON ingest and kernel compute overlap instead of serializing: while
//! the analytics stage folds batch *k* into the incremental kernels
//! ([`IncrementalAnalytics`]), the parse stage is already decoding batch
//! *k + 1*. With pinning enabled and an SMT sibling pair available, the
//! light stages (parse, emit) share one sibling and the analytics stage
//! owns the other — the same placement philosophy as the pool's
//! pair-shards. Inside the analytics stage, delta batches are classified
//! [`Par`]-parallel before the serial authoritative apply, so the
//! fine-grained tasking story extends to the update path itself.
//!
//! Every queue handoff is bounded: a full queue makes the producer spin
//! (counted in [`StreamReport::stalls`]) rather than drop — the
//! pipeline is lossless and order-preserving by construction, and the
//! emit stage *verifies* both (no-drop, no-reorder) rather than
//! assuming them.

use std::sync::Arc;
use std::time::Instant;

use crate::graph::IncrementalAnalytics;
use crate::json::{self, Value};
use crate::relic::affinity::{pin_to_cpu, smt_sibling_pair};
use crate::relic::{Par, Relic, SpscQueue};
use crate::testutil::Rng;

/// Typed view of the `[stream]` config section (defaults here, lenient
/// overlay + validation in [`crate::config::StreamSettings`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamConfig {
    /// Master switch: when false, the serving path is byte-identical to
    /// the non-streaming engine (degeneracy ladder).
    pub enabled: bool,
    /// Vertices = `1 << scale`.
    pub scale: u32,
    /// Edges per delta batch.
    pub batch: usize,
    /// Batches per stream run.
    pub batches: usize,
    /// Capacity of each SPSC stage link (rounded up to a power of two).
    pub queue_capacity: usize,
    /// Rebuild-from-scratch every N batches (0 = never); the escape
    /// hatch that must reproduce the incremental state bit for bit.
    pub recompute_interval: usize,
    /// BFS source vertex.
    pub source: u32,
    /// Seed for the edge-stream generators.
    pub seed: u64,
    /// Pin stages to an SMT sibling pair when the topology offers one.
    pub pin: bool,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            enabled: false,
            scale: 10,
            batch: 256,
            batches: 32,
            queue_capacity: 8,
            recompute_interval: 8,
            source: 0,
            seed: 1,
            pin: true,
        }
    }
}

/// Edge-stream shape for the seeded generators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeDist {
    /// R-MAT quadrant sampling (GAP's Kronecker parameters): skewed
    /// degree distribution, many duplicates — the hard case for the
    /// classify/dedup path.
    PowerLaw,
    /// Independent uniform endpoints.
    Uniform,
}

impl EdgeDist {
    /// Stable name used in config, CLI, and artifact rows.
    pub fn name(self) -> &'static str {
        match self {
            EdgeDist::PowerLaw => "power-law",
            EdgeDist::Uniform => "uniform",
        }
    }

    /// Inverse of [`EdgeDist::name`].
    pub fn parse(s: &str) -> Option<EdgeDist> {
        match s {
            "power-law" => Some(EdgeDist::PowerLaw),
            "uniform" => Some(EdgeDist::Uniform),
            _ => None,
        }
    }

    /// Both scenarios, sweep order.
    pub fn all() -> [EdgeDist; 2] {
        [EdgeDist::PowerLaw, EdgeDist::Uniform]
    }
}

/// R-MAT quadrant probabilities (GAP: A=0.57, B=0.19, C=0.19, D=0.05).
const RMAT_A: f64 = 0.57;
const RMAT_B: f64 = 0.19;
const RMAT_C: f64 = 0.19;

/// Generate `batches` delta batches of `batch` edges each over
/// `1 << scale` vertices. Deterministic in `seed`; self-loops and
/// duplicates are left in on purpose (the apply path must reject them).
pub fn generate_batches(
    dist: EdgeDist,
    scale: u32,
    batches: usize,
    batch: usize,
    seed: u64,
) -> Vec<Vec<(u32, u32)>> {
    let n = 1u64 << scale;
    let mut rng = Rng::new(seed ^ 0x5752_4D41_5453_7472);
    (0..batches)
        .map(|_| {
            (0..batch)
                .map(|_| match dist {
                    EdgeDist::Uniform => (rng.below(n) as u32, rng.below(n) as u32),
                    EdgeDist::PowerLaw => {
                        let (mut u, mut v) = (0u32, 0u32);
                        for bit in 0..scale {
                            let r = rng.f64();
                            if r < RMAT_A {
                                // top-left quadrant: neither bit set
                            } else if r < RMAT_A + RMAT_B {
                                v |= 1 << bit;
                            } else if r < RMAT_A + RMAT_B + RMAT_C {
                                u |= 1 << bit;
                            } else {
                                u |= 1 << bit;
                                v |= 1 << bit;
                            }
                        }
                        (u, v)
                    }
                })
                .collect()
        })
        .collect()
}

/// Encode one delta batch in the stream wire format:
/// `{"seq": N, "edges": [[u, v], ...]}`.
pub fn encode_batch(seq: u64, edges: &[(u32, u32)]) -> Vec<u8> {
    let edges = edges
        .iter()
        .map(|&(u, v)| {
            Value::Array(vec![Value::Number(u as f64), Value::Number(v as f64)])
        })
        .collect();
    let doc = Value::Object(vec![
        ("seq".into(), Value::Number(seq as f64)),
        ("edges".into(), Value::Array(edges)),
    ]);
    json::to_string(&doc).into_bytes()
}

/// Decode a parsed wire document back into `(seq, edges)`. Strict:
/// missing fields, wrong shapes, fractional or out-of-range endpoints
/// are all rejected (the parse stage counts these, it never applies
/// them).
pub fn decode_batch(doc: &Value) -> Result<(u64, Vec<(u32, u32)>), &'static str> {
    let seq = doc.get("seq").and_then(Value::as_u64).ok_or("missing or invalid seq")?;
    let arr = doc.get("edges").and_then(Value::as_array).ok_or("missing edges array")?;
    let mut edges = Vec::with_capacity(arr.len());
    for e in arr {
        let pair = e.as_array().ok_or("edge is not a 2-array")?;
        if pair.len() != 2 {
            return Err("edge is not a 2-array");
        }
        let u = pair[0].as_u64().ok_or("edge endpoint is not an integer")?;
        let v = pair[1].as_u64().ok_or("edge endpoint is not an integer")?;
        if u > u32::MAX as u64 || v > u32::MAX as u64 {
            return Err("edge endpoint exceeds u32");
        }
        edges.push((u as u32, v as u32));
    }
    Ok((seq, edges))
}

/// Generate and encode a whole stream for one scenario (the sweep's and
/// the tests' input builder).
pub fn encode_stream(dist: EdgeDist, cfg: &StreamConfig) -> Vec<Vec<u8>> {
    generate_batches(dist, cfg.scale, cfg.batches, cfg.batch, cfg.seed)
        .iter()
        .enumerate()
        .map(|(i, edges)| encode_batch(i as u64, edges))
        .collect()
}

/// A raw wire document entering the pipeline.
struct Doc {
    index: u64,
    bytes: Vec<u8>,
}

/// Parse-stage output: the decoded batch, or the reason it was rejected.
struct Parsed {
    index: u64,
    payload: Result<(u64, Vec<(u32, u32)>), &'static str>,
}

/// Analytics-stage output: one emit record per input document.
struct Record {
    index: u64,
    seq: u64,
    accepted: usize,
    rejected: usize,
    recomputed: bool,
    recompute_matched: bool,
    checksums: (u64, u64, u64),
    error: Option<&'static str>,
}

/// Stage message: an item, or the upstream's end-of-stream marker.
enum Msg<T> {
    Item(T),
    Done,
}

/// Push with bounded-queue backpressure: spin-retry until the consumer
/// frees a slot, counting each failed attempt as a stall.
fn push_blocking<T>(q: &SpscQueue<Msg<T>>, mut msg: Msg<T>, stalls: &mut u64) {
    loop {
        match q.push(msg) {
            Ok(()) => return,
            Err(back) => {
                msg = back;
                *stalls += 1;
                std::thread::yield_now();
            }
        }
    }
}

/// Pop, yielding while the queue is empty.
fn pop_blocking<T>(q: &SpscQueue<Msg<T>>) -> Msg<T> {
    loop {
        match q.pop() {
            Some(msg) => return msg,
            None => std::thread::yield_now(),
        }
    }
}

/// Aggregate result of one pipeline run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Documents fed into the pipeline.
    pub batches_in: u64,
    /// Documents rejected at the parse stage (malformed JSON or wire
    /// shape); they still flow through as error records — never dropped.
    pub parse_errors: u64,
    /// Edges offered across all well-formed batches.
    pub edges_offered: u64,
    /// Edges actually inserted.
    pub edges_accepted: u64,
    /// Self-loops, duplicates, out-of-range endpoints.
    pub edges_rejected: u64,
    /// Escape-hatch rebuilds performed.
    pub recomputes: u64,
    /// Escape-hatch rebuilds that did NOT bitwise-match the incremental
    /// state (hard-gated to 0 by `repro stream` and the tests).
    pub recompute_mismatches: u64,
    /// Emit-stage order violations (hard-gated to 0).
    pub out_of_order: u64,
    /// Backpressure stall counts per stage link: `[driver→parse,
    /// parse→analytics, analytics→emit]`.
    pub stalls: [u64; 3],
    /// Wall-clock for the whole run.
    pub elapsed_ms: f64,
    /// Accepted edge insertions per second of wall-clock.
    pub updates_per_sec: f64,
    /// Whether the stages were actually pinned to an SMT sibling pair.
    pub pinned: bool,
    /// Final `(cc, pr, bfs)` checksums of the incremental state.
    pub checksums: (u64, u64, u64),
    /// One JSON line per input document, in input order.
    pub emitted: Vec<String>,
}

impl StreamReport {
    /// Compact counter view for [`crate::coordinator::Engine::report`].
    pub fn snapshot(&self) -> StreamSnapshot {
        StreamSnapshot {
            batches: self.batches_in,
            updates: self.edges_accepted,
            updates_per_sec: self.updates_per_sec,
            parse_errors: self.parse_errors,
            recomputes: self.recomputes,
            stalls: self.stalls,
        }
    }
}

/// Stream counters surfaced in the engine's operator report.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSnapshot {
    /// Documents processed.
    pub batches: u64,
    /// Edge insertions applied.
    pub updates: u64,
    /// Insertions per second of pipeline wall-clock.
    pub updates_per_sec: f64,
    /// Malformed documents rejected at parse.
    pub parse_errors: u64,
    /// Escape-hatch rebuilds.
    pub recomputes: u64,
    /// Backpressure stalls per stage link.
    pub stalls: [u64; 3],
}

/// Serialize one analytics record as an emit line. Checksums travel as
/// strings: they are u64 bit-reductions and must survive the f64-backed
/// JSON number type losslessly.
fn record_to_line(rec: &Record) -> String {
    let mut fields = vec![
        ("seq".to_string(), Value::Number(rec.seq as f64)),
        ("accepted".to_string(), Value::Number(rec.accepted as f64)),
        ("rejected".to_string(), Value::Number(rec.rejected as f64)),
        ("cc".to_string(), Value::String(rec.checksums.0.to_string())),
        ("pr".to_string(), Value::String(rec.checksums.1.to_string())),
        ("bfs".to_string(), Value::String(rec.checksums.2.to_string())),
        ("recomputed".to_string(), Value::Bool(rec.recomputed)),
    ];
    if let Some(err) = rec.error {
        fields.push(("error".to_string(), Value::String(err.to_string())));
    }
    json::to_string(&Value::Object(fields))
}

/// Run the parse → analytics → emit pipeline over a sequence of wire
/// documents, returning the run report and the final incremental state
/// (so callers can gate it against full-recompute oracles).
///
/// The caller's thread is the driver/producer; the three stages are
/// spawned threads. With `cfg.pin` and an SMT pair `(a, b)` available,
/// parse and emit share sibling `a` and analytics owns sibling `b`;
/// without a pair (or with pinning off) all stages float. The analytics
/// stage owns an unpinned [`Relic`] runtime for `Par`-parallel batch
/// classification.
pub fn run_pipeline(
    cfg: &StreamConfig,
    docs: Vec<Vec<u8>>,
) -> (StreamReport, IncrementalAnalytics) {
    let n = 1usize << cfg.scale;
    let source = cfg.source;
    let recompute_interval = cfg.recompute_interval;
    let pair = if cfg.pin { smt_sibling_pair() } else { None };
    let q_in: Arc<SpscQueue<Msg<Doc>>> = Arc::new(SpscQueue::new(cfg.queue_capacity));
    let q_ab: Arc<SpscQueue<Msg<Parsed>>> = Arc::new(SpscQueue::new(cfg.queue_capacity));
    let q_bc: Arc<SpscQueue<Msg<Record>>> = Arc::new(SpscQueue::new(cfg.queue_capacity));

    let start = Instant::now();

    let parse_stage = {
        let (q_in, q_ab) = (Arc::clone(&q_in), Arc::clone(&q_ab));
        std::thread::spawn(move || {
            if let Some((a, _)) = pair {
                pin_to_cpu(a);
            }
            let mut parse_errors = 0u64;
            let mut stalls = 0u64;
            loop {
                match pop_blocking(&q_in) {
                    Msg::Done => {
                        push_blocking(&q_ab, Msg::Done, &mut stalls);
                        return (parse_errors, stalls);
                    }
                    Msg::Item(doc) => {
                        let payload = json::parse(&doc.bytes)
                            .map_err(|_| "malformed JSON")
                            .and_then(|v| decode_batch(&v));
                        if payload.is_err() {
                            parse_errors += 1;
                        }
                        let item = Parsed { index: doc.index, payload };
                        push_blocking(&q_ab, Msg::Item(item), &mut stalls);
                    }
                }
            }
        })
    };

    let analytics_stage = {
        let (q_ab, q_bc) = (Arc::clone(&q_ab), Arc::clone(&q_bc));
        std::thread::spawn(move || {
            if let Some((_, b)) = pair {
                pin_to_cpu(b);
            }
            let relic = Relic::new();
            let par = Par::Relic(&relic);
            let mut an = IncrementalAnalytics::empty(n, source, recompute_interval);
            let mut offered = 0u64;
            let mut accepted = 0u64;
            let mut rejected = 0u64;
            let mut stalls = 0u64;
            loop {
                match pop_blocking(&q_ab) {
                    Msg::Done => {
                        push_blocking(&q_bc, Msg::Done, &mut stalls);
                        break;
                    }
                    Msg::Item(parsed) => {
                        let rec = match parsed.payload {
                            Ok((seq, edges)) => {
                                offered += edges.len() as u64;
                                let out = an.apply_batch(&edges, &par);
                                accepted += out.accepted as u64;
                                rejected += out.rejected as u64;
                                Record {
                                    index: parsed.index,
                                    seq,
                                    accepted: out.accepted,
                                    rejected: out.rejected,
                                    recomputed: out.recomputed,
                                    recompute_matched: out.recompute_matched,
                                    checksums: an.checksums(),
                                    error: None,
                                }
                            }
                            Err(reason) => Record {
                                index: parsed.index,
                                seq: parsed.index,
                                accepted: 0,
                                rejected: 0,
                                recomputed: false,
                                recompute_matched: true,
                                checksums: an.checksums(),
                                error: Some(reason),
                            },
                        };
                        push_blocking(&q_bc, Msg::Item(rec), &mut stalls);
                    }
                }
            }
            (an, offered, accepted, rejected, stalls)
        })
    };

    let emit_stage = {
        let q_bc = Arc::clone(&q_bc);
        std::thread::spawn(move || {
            if let Some((a, _)) = pair {
                pin_to_cpu(a);
            }
            let mut lines = Vec::new();
            let mut out_of_order = 0u64;
            let mut mismatches = 0u64;
            let mut expected = 0u64;
            loop {
                match pop_blocking(&q_bc) {
                    Msg::Done => return (lines, out_of_order, mismatches),
                    Msg::Item(rec) => {
                        if rec.index != expected {
                            out_of_order += 1;
                        }
                        expected = rec.index + 1;
                        if !rec.recompute_matched {
                            mismatches += 1;
                        }
                        lines.push(record_to_line(&rec));
                    }
                }
            }
        })
    };

    let mut stalls_in = 0u64;
    let batches_in = docs.len() as u64;
    for (i, bytes) in docs.into_iter().enumerate() {
        let doc = Doc { index: i as u64, bytes };
        push_blocking(&q_in, Msg::Item(doc), &mut stalls_in);
    }
    push_blocking(&q_in, Msg::Done, &mut stalls_in);

    let (parse_errors, stalls_ab) = parse_stage.join().expect("parse stage panicked");
    let (analytics, edges_offered, edges_accepted, edges_rejected, stalls_bc) =
        analytics_stage.join().expect("analytics stage panicked");
    let (emitted, out_of_order, emit_mismatches) =
        emit_stage.join().expect("emit stage panicked");
    let elapsed = start.elapsed();

    let elapsed_ms = elapsed.as_secs_f64() * 1e3;
    let updates_per_sec = if elapsed.as_secs_f64() > 0.0 {
        edges_accepted as f64 / elapsed.as_secs_f64()
    } else {
        0.0
    };
    debug_assert_eq!(emit_mismatches, analytics.recompute_mismatches());
    let report = StreamReport {
        batches_in,
        parse_errors,
        edges_offered,
        edges_accepted,
        edges_rejected,
        recomputes: analytics.recomputes(),
        recompute_mismatches: analytics.recompute_mismatches(),
        out_of_order,
        stalls: [stalls_in, stalls_ab, stalls_bc],
        elapsed_ms,
        updates_per_sec,
        pinned: pair.is_some(),
        checksums: analytics.checksums(),
        emitted,
    };
    (report, analytics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{bfs, cc, oracle, pr};
    use crate::probe::NoProbe;

    fn tiny_cfg() -> StreamConfig {
        StreamConfig {
            enabled: true,
            scale: 6,
            batch: 32,
            batches: 12,
            queue_capacity: 4,
            recompute_interval: 4,
            source: 0,
            seed: 7,
            pin: false,
        }
    }

    #[test]
    fn wire_roundtrip_preserves_batches() {
        crate::testutil::check(20, |rng| {
            let seq = rng.next_u64() >> 20;
            let edges: Vec<(u32, u32)> = (0..rng.below(40) as usize)
                .map(|_| (rng.below(1 << 20) as u32, rng.below(1 << 20) as u32))
                .collect();
            let doc = encode_batch(seq, &edges);
            let parsed = json::parse(&doc).map_err(|e| format!("{e}"))?;
            let (got_seq, got_edges) =
                decode_batch(&parsed).map_err(|e| e.to_string())?;
            if got_seq != seq || got_edges != edges {
                return Err("round trip mutated the batch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_malformed_shapes() {
        let cases: &[&[u8]] = &[
            br#"{"edges": []}"#,                          // missing seq
            br#"{"seq": -1, "edges": []}"#,               // negative seq
            br#"{"seq": 1.5, "edges": []}"#,              // fractional seq
            br#"{"seq": 0}"#,                             // missing edges
            br#"{"seq": 0, "edges": 3}"#,                 // edges not an array
            br#"{"seq": 0, "edges": [[1]]}"#,             // arity 1
            br#"{"seq": 0, "edges": [[1, 2, 3]]}"#,       // arity 3
            br#"{"seq": 0, "edges": [[1, "a"]]}"#,        // non-numeric endpoint
            br#"{"seq": 0, "edges": [[1, 2.5]]}"#,        // fractional endpoint
            br#"{"seq": 0, "edges": [[1, 4294967296]]}"#, // > u32::MAX
        ];
        for c in cases {
            let v = json::parse(c).expect("valid JSON shape test");
            assert!(
                decode_batch(&v).is_err(),
                "should reject: {}",
                String::from_utf8_lossy(c)
            );
        }
    }

    #[test]
    fn generators_are_deterministic_and_distinct() {
        for dist in EdgeDist::all() {
            let a = generate_batches(dist, 8, 4, 64, 9);
            let b = generate_batches(dist, 8, 4, 64, 9);
            assert_eq!(a, b, "{} must be seed-deterministic", dist.name());
            let c = generate_batches(dist, 8, 4, 64, 10);
            assert_ne!(a, c, "{} must vary with the seed", dist.name());
        }
        let pl = generate_batches(EdgeDist::PowerLaw, 8, 2, 64, 9);
        let un = generate_batches(EdgeDist::Uniform, 8, 2, 64, 9);
        assert_ne!(pl, un, "scenarios must differ");
    }

    #[test]
    fn edge_dist_names_roundtrip() {
        for dist in EdgeDist::all() {
            assert_eq!(EdgeDist::parse(dist.name()), Some(dist));
        }
        assert_eq!(EdgeDist::parse("zipf"), None);
    }

    #[test]
    fn pipeline_is_lossless_ordered_and_oracle_consistent() {
        let cfg = tiny_cfg();
        for dist in EdgeDist::all() {
            let docs = encode_stream(dist, &cfg);
            let (report, analytics) = run_pipeline(&cfg, docs);
            assert_eq!(report.batches_in, cfg.batches as u64);
            assert_eq!(report.emitted.len(), cfg.batches, "no drops");
            assert_eq!(report.out_of_order, 0, "no reorders");
            assert_eq!(report.parse_errors, 0);
            assert_eq!(report.recompute_mismatches, 0);
            assert_eq!(report.recomputes, (cfg.batches / cfg.recompute_interval) as u64);
            assert_eq!(
                report.edges_offered,
                (cfg.batches * cfg.batch) as u64,
                "classification saw every offered edge"
            );
            assert_eq!(
                report.edges_accepted + report.edges_rejected,
                report.edges_offered
            );
            // Final state equals full recomputes on the rebuilt graph.
            let g = analytics.graph().rebuild();
            assert_eq!(analytics.cc_labels(), oracle::components_min_label(&g));
            assert_eq!(analytics.bfs_depths(), oracle::bfs_depths(&g, cfg.source));
            let kernel = pr::pagerank(&g, pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe);
            assert_eq!(
                analytics.pr_scores().iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                kernel.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                "{}: pr bitwise", dist.name()
            );
            assert_eq!(
                report.checksums,
                (
                    cc::checksum(&analytics.cc_labels()),
                    pr::checksum(analytics.pr_scores()),
                    bfs::checksum(analytics.bfs_depths()),
                )
            );
        }
    }

    #[test]
    fn pipeline_counts_malformed_docs_without_dropping() {
        let cfg = tiny_cfg();
        let mut docs = encode_stream(EdgeDist::Uniform, &cfg);
        docs[3] = b"{\"seq\": 3, \"edges\": [[1".to_vec(); // truncated
        docs[7] = b"not json at all".to_vec();
        let total = docs.len();
        let (report, _) = run_pipeline(&cfg, docs);
        assert_eq!(report.parse_errors, 2);
        assert_eq!(report.emitted.len(), total, "error records still emitted");
        assert_eq!(report.out_of_order, 0);
        let line3 = &report.emitted[3];
        assert!(line3.contains("\"error\""), "line carries the reason: {line3}");
    }

    #[test]
    fn pipeline_is_deterministic_across_runs() {
        let cfg = tiny_cfg();
        let docs = encode_stream(EdgeDist::PowerLaw, &cfg);
        let (r1, a1) = run_pipeline(&cfg, docs.clone());
        let (r2, a2) = run_pipeline(&cfg, docs);
        assert_eq!(r1.emitted, r2.emitted, "emit lines are seed-deterministic");
        assert_eq!(r1.checksums, r2.checksums);
        assert_eq!(a1.cc_labels(), a2.cc_labels());
        assert_eq!(
            a1.pr_scores().iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            a2.pr_scores().iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a1.bfs_depths(), a2.bfs_depths());
    }

    #[test]
    fn snapshot_compacts_the_report() {
        let cfg = tiny_cfg();
        let docs = encode_stream(EdgeDist::Uniform, &cfg);
        let (report, _) = run_pipeline(&cfg, docs);
        let snap = report.snapshot();
        assert_eq!(snap.batches, report.batches_in);
        assert_eq!(snap.updates, report.edges_accepted);
        assert_eq!(snap.parse_errors, 0);
        assert_eq!(snap.recomputes, report.recomputes);
        assert_eq!(snap.stalls, report.stalls);
    }
}
