//! The sharded service engine: admission over a [`RelicPool`] of
//! pair-shards.
//!
//! [`Coordinator::process_batch`] is synchronous on one embedded SMT
//! pair — the paper's single-core scope. [`Engine`] scales it out while
//! keeping that coordinator *unchanged* as each shard's inner loop:
//!
//! * [`Engine::submit`] tags each [`Request`] with a sequence number
//!   and dispatches it to the least-loaded shard (bounded per-shard
//!   channel, blocking backpressure — see [`crate::relic::pool`]);
//! * every shard thread owns a native-only `Coordinator`; its drained
//!   batches go through `process_batch`, so request pairing and the
//!   odd-leftover intra-request fork-join still happen per shard;
//! * [`Engine::drain`] collects the responses of everything submitted
//!   since the last drain and returns them in submission order;
//! * per-shard [`ServiceMetrics`] plus the pool's admission counters
//!   aggregate into one service-level [`Engine::report`].
//!
//! Shards run the native kernels only: PJRT executors hold process-wide
//! device state and are not replicated per shard — coarse offload stays
//! on the single-pair [`Coordinator`] path (`repro serve` without
//! `--shards`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::config::PoolSettings;
use crate::relic::pool::{discover_placements, PoolConfig, PoolSnapshot, RelicPool};
use crate::relic::RelicConfig;

use super::router::{Router, RouterConfig};
use super::service::{Coordinator, Request, Response, ServiceMetrics};

/// Engine configuration: pool sizing/placement plus routing.
#[derive(Debug, Clone, Default)]
pub struct EngineConfig {
    pub pool: PoolConfig,
    pub router: RouterConfig,
}

impl EngineConfig {
    /// Default configuration with an explicit shard count (`None` = one
    /// shard per detected physical core).
    pub fn with_shards(shards: Option<usize>) -> Self {
        EngineConfig {
            pool: PoolConfig { shards, ..PoolConfig::default() },
            ..EngineConfig::default()
        }
    }

    /// Build from the `[pool]` section of a config file.
    pub fn from_settings(s: &PoolSettings) -> Self {
        EngineConfig {
            pool: PoolConfig {
                shards: s.shard_count_hint(),
                pin: s.pin,
                channel_capacity: s.channel_capacity,
                max_batch: s.max_batch,
            },
            router: RouterConfig::default(),
        }
    }
}

/// A request tagged with its admission sequence number.
struct Sequenced {
    seq: u64,
    req: Request,
}

/// The sharded analytics engine.
pub struct Engine {
    pool: RelicPool<Sequenced>,
    responses: Receiver<(u64, Response)>,
    /// Responses received but not yet handed out by `drain`.
    collected: Vec<(u64, Response)>,
    /// Requests submitted since the last completed `drain`.
    pending: usize,
    next_seq: u64,
    shard_metrics: Vec<Arc<ServiceMetrics>>,
}

impl Engine {
    /// Spawn the engine: discover placements, then one shard per
    /// placement, each building its own native-only [`Coordinator`]
    /// (and with it its Relic pair) on the shard thread.
    pub fn new(config: EngineConfig) -> Self {
        let placements = discover_placements(config.pool.shards, config.pool.pin);
        let shard_metrics: Vec<Arc<ServiceMetrics>> =
            placements.iter().map(|_| Arc::new(ServiceMetrics::default())).collect();
        let (tx, rx): (Sender<(u64, Response)>, _) = channel();
        let factory = {
            let shard_metrics = shard_metrics.clone();
            let router_cfg = config.router.clone();
            move |p: &crate::relic::ShardPlacement| {
                Coordinator::with_config(
                    Router::new(router_cfg.clone(), None),
                    None,
                    RelicConfig { assistant_cpu: p.assistant_cpu, ..RelicConfig::default() },
                    Arc::clone(&shard_metrics[p.shard]),
                )
            }
        };
        let handler = move |coord: &mut Coordinator, batch: Vec<Sequenced>| {
            let seqs: Vec<u64> = batch.iter().map(|s| s.seq).collect();
            let reqs: Vec<Request> = batch.into_iter().map(|s| s.req).collect();
            for (seq, resp) in seqs.into_iter().zip(coord.process_batch(reqs)) {
                // A send can only fail when the engine (receiver) is
                // already gone — the shard is being torn down anyway.
                let _ = tx.send((seq, resp));
            }
        };
        let pool = RelicPool::with_placements(placements, &config.pool, factory, handler);
        Engine {
            pool,
            responses: rx,
            collected: Vec::new(),
            pending: 0,
            next_seq: 0,
            shard_metrics,
        }
    }

    /// Number of shards serving requests.
    pub fn shard_count(&self) -> usize {
        self.pool.shard_count()
    }

    /// Dispatch one request to the least-loaded shard. Returns the
    /// shard it went to. Blocks only under backpressure (the chosen
    /// shard's bounded channel is full).
    pub fn submit(&mut self, req: Request) -> usize {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending += 1;
        self.pool.submit(Sequenced { seq, req })
    }

    /// Wait for every response to the requests submitted since the last
    /// drain and return them **in submission order**.
    ///
    /// # Panics
    /// Panics if a shard thread dies (its handler panicked) while
    /// responses are outstanding — the alternative is waiting forever
    /// for responses the dead shard can no longer send.
    pub fn drain(&mut self) -> Vec<Response> {
        use std::sync::mpsc::RecvTimeoutError;
        while self.collected.len() < self.pending {
            match self.responses.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(item) => self.collected.push(item),
                Err(RecvTimeoutError::Timeout) => {
                    let dead = self.pool.dead_shards();
                    assert!(
                        dead.is_empty(),
                        "engine shard(s) {dead:?} died with {} responses outstanding",
                        self.pending - self.collected.len()
                    );
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!(
                        "every engine shard died with {} responses outstanding",
                        self.pending - self.collected.len()
                    );
                }
            }
        }
        self.pending = 0;
        let mut out = std::mem::take(&mut self.collected);
        out.sort_by_key(|(seq, _)| *seq);
        out.into_iter().map(|(_, resp)| resp).collect()
    }

    /// Drop-in replacement for [`Coordinator::process_batch`]: submit
    /// the whole batch, then drain — responses in request order.
    pub fn process_batch(&mut self, requests: Vec<Request>) -> Vec<Response> {
        for req in requests {
            self.submit(req);
        }
        self.drain()
    }

    /// Pool-level admission counters and per-shard occupancy.
    pub fn pool_snapshot(&self) -> PoolSnapshot {
        self.pool.snapshot()
    }

    /// Metrics of one shard's coordinator.
    pub fn shard_metrics(&self, shard: usize) -> &ServiceMetrics {
        &self.shard_metrics[shard]
    }

    /// Service-level metrics: every shard's [`ServiceMetrics`] folded
    /// into one aggregate.
    pub fn aggregated_metrics(&self) -> ServiceMetrics {
        let agg = ServiceMetrics::default();
        for m in &self.shard_metrics {
            agg.merge_from(m);
        }
        agg
    }

    /// Human-readable report: pool counters, one line per shard, and
    /// the aggregated service metrics.
    pub fn report(&self) -> String {
        let snap = self.pool.snapshot();
        let mut out = format!(
            "pool: {} shards, {} dispatched, {} backpressure stalls\n",
            snap.shards, snap.dispatched, snap.backpressure_stalls
        );
        for (i, m) in self.shard_metrics.iter().enumerate() {
            let p = self.pool.placement(i);
            let cpus = match (p.main_cpu, p.assistant_cpu) {
                (Some(a), Some(b)) => format!("cpus {a}+{b}"),
                _ => "unpinned".into(),
            };
            out += &format!(
                "shard {i} [{cpus}]: {} reqs ({} pairs, {} intra), {} served\n",
                m.native_requests.get(),
                m.relic_pairs.get(),
                m.intra_requests.get(),
                snap.occupancy[i],
            );
        }
        let agg = self.aggregated_metrics();
        out += &format!(
            "total: {} native reqs {}\n",
            agg.native_requests.get(),
            agg.native_latency.summary("ns"),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_native_kernel, Backend, GraphKernel, RequestResult};
    use crate::graph::kronecker::paper_graph;

    fn engine(shards: usize) -> Engine {
        // Unpinned in tests: CI containers may refuse affinity calls.
        Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(shards), pin: false, ..PoolConfig::default() },
            ..EngineConfig::default()
        })
    }

    fn req(id: u64, kernel: GraphKernel) -> Request {
        Request { id, kernel, graph: paper_graph(), source: 0 }
    }

    #[test]
    fn responses_in_submission_order_with_correct_checksums() {
        let mut e = engine(3);
        let kernels = GraphKernel::all();
        let expected: Vec<u64> =
            kernels.iter().map(|&k| run_native_kernel(k, &paper_graph(), 0)).collect();
        for round in 0..3 {
            for (i, &k) in kernels.iter().enumerate() {
                e.submit(req((round * 10 + i) as u64, k));
            }
            let responses = e.drain();
            assert_eq!(responses.len(), kernels.len());
            for (i, r) in responses.iter().enumerate() {
                assert_eq!(r.id, (round * 10 + i) as u64, "submission order");
                assert_eq!(r.backend, Backend::Native);
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[i]),
                    "round {round} kernel {:?}",
                    kernels[i]
                );
            }
        }
    }

    #[test]
    fn single_shard_matches_single_pair_coordinator() {
        let mut single = Coordinator::with_parts(
            Router::new(RouterConfig::default(), None),
            None,
        );
        let mixed = |n: u64| -> Vec<Request> {
            (0..n).map(|i| req(i, GraphKernel::all()[i as usize % 6])).collect()
        };
        let reqs = mixed(7);
        let want = single.process_batch(mixed(7));
        let mut e = engine(1);
        let got = e.process_batch(reqs);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.backend, w.backend);
            assert_eq!(g.result, w.result);
        }
        assert_eq!(e.aggregated_metrics().native_requests.get(), 7);
    }

    #[test]
    fn metrics_aggregate_across_shards() {
        let mut e = engine(2);
        let n = 24;
        for i in 0..n {
            e.submit(req(i, GraphKernel::Tc));
        }
        let responses = e.drain();
        assert_eq!(responses.len(), n as usize);
        let agg = e.aggregated_metrics();
        assert_eq!(agg.native_requests.get(), n);
        assert_eq!(agg.native_latency.count(), n, "one latency sample per request");
        let snap = e.pool_snapshot();
        assert_eq!(snap.dispatched, n);
        assert_eq!(snap.occupancy.iter().sum::<u64>(), n);
        let report = e.report();
        assert!(report.contains("pool: 2 shards"));
        assert!(report.contains("shard 0"));
        assert!(report.contains("total:"));
    }

    #[test]
    fn empty_drain_is_fine() {
        let mut e = engine(2);
        assert!(e.drain().is_empty());
        assert!(e.process_batch(Vec::new()).is_empty());
    }
}
