//! The sharded service engine: admission over a [`RelicPool`] of
//! pair-shards.
//!
//! [`Coordinator::process_batch`] is synchronous on one embedded SMT
//! pair — the paper's single-core scope. [`Engine`] scales it out while
//! keeping that coordinator *unchanged* as each shard's inner loop:
//!
//! * every [`Request`] that passes admission is tagged with a sequence
//!   number and dispatched to the shard with the least estimated wait
//!   (bounded per-shard channel — see [`crate::relic::pool`] and
//!   [`super::router::pick_shard_leased`], which also steers small
//!   requests away from shards currently lent to a whale). The wait
//!   estimate is *measured*:
//!   each shard's [`ServiceMetrics`] carries a per-kernel-class
//!   service-time EMA ([`crate::metrics::ServiceEstimator`]) fed by
//!   `record_completion` and read lock-free at admission, with the
//!   static `service_estimate_ns` knob as its seed/floor (`ema_alpha
//!   == 0` keeps the knob authoritative — the PR 4 behavior);
//! * with `edf` enabled each shard serves the deadline-carrying
//!   requests of a drained batch earliest-deadline-first
//!   ([`super::admission::edf_order`]) while deadline-less requests
//!   keep FIFO order among themselves — response order and the no-drop
//!   guarantee are unchanged;
//! * the **front door** comes in three flavors sharing one admission
//!   gate (shed policy + routing + slack accounting):
//!   [`Engine::submit`] blocks on a full channel (PR 2's counted
//!   backpressure, bit-for-bit under
//!   [`ShedPolicy::Never`](super::admission::ShedPolicy::Never)),
//!   [`Engine::try_submit`] returns [`Admission::QueueFull`] with the
//!   request instead of waiting, and [`Engine::submit_or_park`] parks
//!   the producer on the shard's drain signal until its consumer frees
//!   capacity;
//! * the gate **sheds at admission, never inside shards**: a request
//!   that can no longer meet its [`Deadline`](super::admission::Deadline)
//!   (or arrives over the load-factor threshold) is refused up front — once accepted it is
//!   part of a shard's FIFO and will be served, so "accepted requests
//!   are never dropped and never reordered per shard" stays an
//!   invariant rather than a best effort. Every shed is counted in
//!   [`crate::metrics::AdmissionMetrics`];
//! * every shard thread owns a native-only `Coordinator`; its drained
//!   batches go through `process_batch`, so request pairing and the
//!   odd-leftover intra-request fork-join still happen per shard;
//! * with `max_borrow > 0` the engine builds a
//!   [`LeaseBroker`] and idle shards serve **cross-shard leases**
//!   between queue polls: one whale request fans its parallel loops out
//!   to `2 × (1 + borrowed)` hardware threads, bitwise-identically to
//!   the single-pair result, and a borrowed shard returns to its own
//!   queue at the next chunk boundary the moment real work arrives (see
//!   `ARCHITECTURE.md` §Cross-shard cooperation). `max_borrow = 0` (the
//!   default) constructs none of this — the pre-borrowing data path,
//!   structurally;
//! * [`Engine::drain`] collects the responses of everything *accepted*
//!   since the last drain and returns them in submission order;
//! * per-shard [`ServiceMetrics`] plus the engine's own admission-side
//!   counters aggregate into one service-level [`Engine::report`].
//!
//! # Failure domains
//!
//! Each shard is a failure domain (see `ARCHITECTURE.md` §Failure
//! domains & recovery). Three containment layers keep a fault from
//! taking the engine down, and a driven watchdog recovers the shard:
//!
//! * **per-request** — the coordinator catches kernel panics and
//!   answers [`RequestResult::Failed`] instead of unwinding
//!   ([`Coordinator::set_fault`] injects them deterministically);
//! * **per-batch** — the shard handler wraps `process_batch` in
//!   `catch_unwind`, so a coordinator-level panic answers the whole
//!   batch with typed failures rather than killing the thread silently;
//! * **per-shard** — the pool's thread loop is the backstop
//!   ([`crate::relic::pool`]); a shard that dies anyway is detected by
//!   the [`Supervisor`], quarantined (routing skips it), its queued
//!   requests are stolen and re-routed exactly once, and the thread is
//!   respawned within a restart budget.
//!
//! With *every* shard quarantined the engine degrades to inline serial
//! execution at the gate ([`Admission::Degraded`]) — answers keep
//! coming, just without parallelism. Responses that are genuinely lost
//! (a fault dropped them, or a shard died past its budget) are
//! synthesized as [`FaultKind::ResponseLost`] once the pool is
//! provably idle, so the no-drop invariant — every accepted request
//! gets exactly one response — holds even under injected chaos.
//! `supervisor.enabled = false` removes all of this: dead shards are
//! fatal again, bit-for-bit the PR 5 engine.
//!
//! Shards run the native kernels only: PJRT executors hold process-wide
//! device state and are not replicated per shard — coarse offload stays
//! on the single-pair [`Coordinator`] path (`repro serve` without
//! `--shards`).

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::{AdmissionSettings, PoolSettings, SupervisorSettings};
use crate::relic::pool::{
    discover_placements, BudgetPolicy, IdleHook, PoolConfig, PoolSnapshot, RelicPool, ShardHealth,
    Supervisor, SupervisorConfig,
};
use crate::relic::{CrossCtx, ExecutionPlan, FaultKind, LeaseBroker, LeaseStats, RelicConfig};

use super::admission::{shed_decision, Admission, AdmissionConfig, ShedReason};
use super::reliability::{
    HealthReport, ReliabilityConfig, ReplayBook, ReplayVerdict, ShardHealthRow,
};
use super::router::{pick_shard_leased, Router, RouterConfig};
use super::service::{Coordinator, Request, RequestResult, Response, ServiceMetrics};
use super::tuner::{shape_name, Tuner, TunerConfig};
use super::{run_native_kernel, Backend};

/// Engine configuration: pool sizing/placement, routing, admission
/// control, and the shard watchdog.
#[derive(Debug, Clone, Default)]
pub struct EngineConfig {
    pub pool: PoolConfig,
    pub router: RouterConfig,
    pub admission: AdmissionConfig,
    /// Watchdog policy. `enabled` defaults to true; no-fault traffic
    /// never reaches the supervisor (it runs only on drain timeouts),
    /// so the degenerate cost is zero. `enabled = false` restores the
    /// PR 5 failure semantics exactly.
    pub supervisor: SupervisorConfig,
    /// Cross-shard borrowing: how many idle sibling shards one whale
    /// request may borrow for its parallel loops (`[relic] max_borrow`).
    /// `0` (the default) builds no [`LeaseBroker`] at all — bit-for-bit
    /// the pre-borrowing engine.
    pub max_borrow: usize,
    /// Maximum queue depth at which a shard is still offered to a whale
    /// (`[pool] offer_depth`). Only read when `max_borrow > 0`.
    pub offer_depth: usize,
    /// At-least-once replay (`[reliability]`). `replay = false` (the
    /// default) retains no requests and replays nothing — bit-for-bit
    /// the at-most-once engine.
    pub reliability: ReliabilityConfig,
    /// Online plan tuning (`[tuner]`). `None` (the default) installs no
    /// tuner anywhere — bit-for-bit the pre-plan engine.
    pub tuner: Option<TunerConfig>,
    /// Force one [`ExecutionPlan`] on every native request (`--plan`).
    /// `None` (the default) forces nothing; a forced plan wins over the
    /// tuner.
    pub plan: Option<ExecutionPlan>,
}

impl EngineConfig {
    /// Default configuration with an explicit shard count (`None` = one
    /// shard per detected physical core).
    pub fn with_shards(shards: Option<usize>) -> Self {
        EngineConfig {
            pool: PoolConfig { shards, ..PoolConfig::default() },
            ..EngineConfig::default()
        }
    }

    /// Build from the `[pool]`, `[admission]`, and `[supervisor]`
    /// sections of a config file (the `[fault]` plan is injected
    /// separately via `pool.fault` — it is a test/repro tool, not an
    /// operating mode).
    pub fn from_settings(
        pool: &PoolSettings,
        admission: &AdmissionSettings,
        supervisor: &SupervisorSettings,
    ) -> Self {
        EngineConfig {
            pool: PoolConfig {
                shards: pool.shard_count_hint(),
                pin: pool.pin,
                channel_capacity: pool.channel_capacity,
                max_batch: pool.max_batch,
                park_timeout: Duration::from_millis(pool.park_timeout_ms),
                fault: None,
            },
            router: RouterConfig::default(),
            admission: admission.to_config(),
            supervisor: supervisor.to_config(),
            // `[relic] max_borrow` is not part of these three sections;
            // the CLI overlays it after this call (serve / repro whale),
            // exactly as it overlays `[reliability]`.
            max_borrow: 0,
            offer_depth: pool.offer_depth,
            reliability: ReliabilityConfig::default(),
            // `[tuner]` / `--plan` are likewise CLI overlays.
            tuner: None,
            plan: None,
        }
    }
}

/// A request tagged with its admission sequence number.
struct Sequenced {
    seq: u64,
    req: Request,
}

/// Per-shard state owned by the shard thread: the coordinator plus the
/// shard's own index (the fault hooks and the panic backstop need to
/// know *which* failure domain they are in).
struct ShardState {
    coord: Coordinator,
    shard: usize,
}

/// Counting semaphore bounding concurrent [`Admission::Degraded`]
/// inline executions. With every shard quarantined, each submitting
/// thread runs its kernel on its own stack; unbounded, a burst of
/// degraded traffic would oversubscribe the very cores the shards were
/// pinned to. The cap defaults to one permit per shard
/// ([`SupervisorConfig::degraded_max_inflight`] `= 0`), i.e. the
/// physical-core count the pool discovered.
struct DegradedGate {
    permits: Mutex<usize>,
    freed: Condvar,
}

impl DegradedGate {
    fn new(permits: usize) -> Self {
        DegradedGate { permits: Mutex::new(permits.max(1)), freed: Condvar::new() }
    }

    /// Block until a permit is free, run `f`, release the permit (also
    /// on panic — the guard is a `Drop`).
    fn run<R>(&self, f: impl FnOnce() -> R) -> R {
        let mut permits = self.permits.lock().expect("degraded gate poisoned");
        while *permits == 0 {
            permits = self.freed.wait(permits).expect("degraded gate poisoned");
        }
        *permits -= 1;
        drop(permits);
        struct Release<'a>(&'a DegradedGate);
        impl Drop for Release<'_> {
            fn drop(&mut self) {
                *self.0.permits.lock().expect("degraded gate poisoned") += 1;
                self.0.freed.notify_one();
            }
        }
        let _release = Release(self);
        f()
    }

    /// Permits currently free (the health surface's occupancy readout).
    fn available(&self) -> usize {
        *self.permits.lock().expect("degraded gate poisoned")
    }
}

/// The sharded analytics engine.
pub struct Engine {
    pool: RelicPool<Sequenced>,
    responses: Receiver<(u64, Response)>,
    /// Responses received but not yet handed out by `drain`.
    collected: Vec<(u64, Response)>,
    /// Requests accepted since the last completed `drain`.
    pending: usize,
    next_seq: u64,
    /// seq → request id for everything accepted but not yet answered —
    /// what the recovery paths consult to synthesize typed failure
    /// responses for requests that can no longer complete.
    in_flight: BTreeMap<u64, u64>,
    admission: AdmissionConfig,
    /// The shard watchdog (`None` = supervision off, PR 5 semantics).
    /// Driven from the drain-timeout path, never from a thread of its
    /// own — a healthy engine pays nothing for it.
    supervisor: Option<Supervisor>,
    shard_metrics: Vec<Arc<ServiceMetrics>>,
    /// Admission-side counters (shed, parked, slack) plus the engine's
    /// fault/recovery counters: recorded here on the submit and
    /// recovery paths, merged with the shard-side metrics (which carry
    /// the completion-side deadline misses and contained panics) in
    /// [`aggregated_metrics`](Self::aggregated_metrics).
    admission_metrics: Arc<ServiceMetrics>,
    /// The cross-shard lease broker — `None` when `max_borrow == 0`
    /// (the default): no broker, no idle hook, no lease checks anywhere
    /// on the data path.
    broker: Option<Arc<LeaseBroker>>,
    /// Bounds concurrent degraded inline executions (see
    /// [`DegradedGate`]).
    degraded_gate: DegradedGate,
    /// The degraded gate's total permit count (for the health surface).
    degraded_permits: usize,
    /// At-least-once replay knobs; `replay = false` short-circuits
    /// every reliability branch on the data path.
    reliability: ReliabilityConfig,
    /// Retained requests for possible replay (empty with replay off).
    replay_book: ReplayBook,
    /// The shared online plan tuner (`None` = tuning off). Ticked once
    /// per settled drain; read/fed by every shard's coordinator.
    tuner: Option<Arc<Tuner>>,
    /// The forced plan, kept for the report line.
    forced_plan: Option<ExecutionPlan>,
    /// The `rebuild` budget-exhausted policy fires at most once.
    rebuilt: bool,
    /// A `drain_and_exit` verdict fired: finish flushing, then the
    /// process should exit nonzero (see [`Engine::exit_requested`]).
    exit_requested: bool,
    /// Counters from the most recent streaming-pipeline run attached
    /// via [`Engine::set_stream`] (`None` = `[stream]` off: the report
    /// is byte-identical to the non-streaming engine's).
    stream: Option<super::stream::StreamSnapshot>,
}

impl Engine {
    /// Spawn the engine: discover placements, then one shard per
    /// placement, each building its own native-only [`Coordinator`]
    /// (and with it its Relic pair) on the shard thread.
    pub fn new(config: EngineConfig) -> Self {
        let placements = discover_placements(config.pool.shards, config.pool.pin);
        let shard_metrics: Vec<Arc<ServiceMetrics>> =
            placements.iter().map(|_| Arc::new(ServiceMetrics::default())).collect();
        // Arm each shard's service-time estimator before any traffic:
        // the static knob seeds/floors the EMA, `ema_alpha == 0` keeps
        // it a pass-through for that knob (PR 4 semantics).
        for m in &shard_metrics {
            m.service_estimator
                .configure(config.admission.ema_alpha, config.admission.service_estimate_ns);
        }
        let supervisor = if config.supervisor.enabled {
            Some(Supervisor::new(config.supervisor.clone(), placements.len()))
        } else {
            None
        };
        // Build the lease broker *before* the pool so the factory can
        // hand every shard's coordinator its `CrossCtx`; the pool's
        // depth/quarantine handles are bound right after construction
        // (an unbound shard is never offered, so the window is safe).
        let broker =
            (config.max_borrow > 0).then(|| Arc::new(LeaseBroker::new(placements.len())));
        // The tuner is built (and optionally smtsim-calibrated) before
        // the pool so the factory can hand every shard a handle — one
        // tuner per engine, arm statistics aggregate across shards.
        let tuner = config.tuner.map(|tc| {
            let t = Arc::new(Tuner::new(tc));
            if tc.calibrate {
                t.calibrate();
            }
            t
        });
        let (tx, rx): (Sender<(u64, Response)>, _) = channel();
        let factory = {
            let shard_metrics = shard_metrics.clone();
            let router_cfg = config.router.clone();
            let edf = config.admission.edf;
            let fault = config.pool.fault.clone();
            let broker = broker.clone();
            let max_borrow = config.max_borrow;
            let offer_depth = config.offer_depth;
            let tuner = tuner.clone();
            let forced_plan = config.plan;
            move |p: &crate::relic::ShardPlacement| {
                let mut coord = Coordinator::with_config(
                    Router::new(router_cfg.clone(), None),
                    None,
                    RelicConfig { assistant_cpu: p.assistant_cpu, ..RelicConfig::default() },
                    Arc::clone(&shard_metrics[p.shard]),
                );
                coord.set_edf(edf);
                coord.set_fault(fault.clone());
                coord.set_cross(broker.as_ref().map(|b| CrossCtx {
                    broker: Arc::clone(b),
                    shard: p.shard,
                    max_borrow,
                    offer_depth,
                }));
                coord.set_tuner(tuner.clone());
                coord.set_plan(forced_plan);
                ShardState { coord, shard: p.shard }
            }
        };
        let handler = {
            let shard_metrics = shard_metrics.clone();
            let fault = config.pool.fault.clone();
            move |state: &mut ShardState, batch: Vec<Sequenced>| {
                let ids: Vec<(u64, u64)> = batch.iter().map(|s| (s.seq, s.req.id)).collect();
                let reqs: Vec<Request> = batch.into_iter().map(|s| s.req).collect();
                match catch_unwind(AssertUnwindSafe(|| state.coord.process_batch(reqs))) {
                    Ok(responses) => {
                        for ((seq, _), resp) in ids.into_iter().zip(responses) {
                            if fault
                                .as_deref()
                                .is_some_and(|p| p.should_drop_response(state.shard))
                            {
                                // Injected response loss: the engine's
                                // idle sweep answers the orphaned seq.
                                continue;
                            }
                            // A send can only fail when the engine
                            // (receiver) is already gone — the shard is
                            // being torn down anyway.
                            let _ = tx.send((seq, resp));
                        }
                    }
                    Err(_) => {
                        // Batch-level containment: the coordinator
                        // panicked *outside* its per-request catch.
                        // Answer every request in the batch with a
                        // typed failure instead of hanging the drain.
                        shard_metrics[state.shard].fault.panics_caught.inc();
                        for (seq, id) in ids {
                            let _ = tx.send((
                                seq,
                                Response {
                                    id,
                                    backend: Backend::Native,
                                    result: RequestResult::Failed(FaultKind::Panic),
                                    latency_ns: 0,
                                },
                            ));
                        }
                    }
                }
            }
        };
        // With a broker, idle shards serve cross-shard leases between
        // 1 ms queue polls instead of blocking on their channel; without
        // one the pool's blocking pop is used unchanged.
        let idle: Option<IdleHook<ShardState>> = broker.as_ref().map(|_| {
            Arc::new(|state: &mut ShardState, should_return: &(dyn Fn() -> bool + Sync)| {
                state.coord.serve_lease(should_return)
            }) as IdleHook<ShardState>
        });
        let pool = RelicPool::with_placements_idle(placements, &config.pool, factory, handler, idle);
        if let Some(b) = &broker {
            for s in 0..pool.shard_count() {
                b.bind(s, pool.depth_handle(s), pool.quarantined_handle(s));
            }
        }
        let degraded_permits = if config.supervisor.degraded_max_inflight == 0 {
            pool.shard_count()
        } else {
            config.supervisor.degraded_max_inflight
        };
        Engine {
            pool,
            responses: rx,
            collected: Vec::new(),
            pending: 0,
            next_seq: 0,
            in_flight: BTreeMap::new(),
            admission: config.admission,
            supervisor,
            shard_metrics,
            admission_metrics: Arc::new(ServiceMetrics::default()),
            broker,
            degraded_gate: DegradedGate::new(degraded_permits),
            degraded_permits: degraded_permits.max(1),
            reliability: config.reliability,
            replay_book: ReplayBook::default(),
            tuner,
            forced_plan: config.plan,
            rebuilt: false,
            exit_requested: false,
            stream: None,
        }
    }

    /// Attach (or clear) the counters of a streaming-pipeline run so
    /// [`Engine::report`] surfaces them. The engine itself never runs
    /// the pipeline — `serve --stream` drives
    /// [`super::stream::run_pipeline`] and hands the snapshot over.
    pub fn set_stream(&mut self, snapshot: Option<super::stream::StreamSnapshot>) {
        self.stream = snapshot;
    }

    /// The engine's online tuner, when `[tuner] enabled = true` built
    /// one (`None` otherwise). Exposes the resolved per-(kernel, shape)
    /// plan table to sweeps and demos.
    pub fn tuner(&self) -> Option<&Arc<Tuner>> {
        self.tuner.as_ref()
    }

    /// Number of shards serving requests.
    pub fn shard_count(&self) -> usize {
        self.pool.shard_count()
    }

    /// The configured admission knobs.
    pub fn admission_config(&self) -> AdmissionConfig {
        self.admission
    }

    /// Whether the shard watchdog is active.
    pub fn supervisor_enabled(&self) -> bool {
        self.supervisor.is_some()
    }

    /// Lease-traffic counters of the cross-shard broker, or `None` when
    /// `max_borrow == 0` and no broker exists.
    pub fn lease_stats(&self) -> Option<LeaseStats> {
        self.broker.as_ref().map(|b| b.stats())
    }

    /// Shards currently quarantined (skipped by routing).
    pub fn quarantined_count(&self) -> usize {
        self.pool.quarantined_count()
    }

    /// Manually quarantine (`true`) or release (`false`) a shard — the
    /// operator override behind the fault sweep's all-down scenario.
    /// Manual quarantines are *not* auto-released by the supervisor;
    /// release them the same way.
    pub fn set_quarantined(&self, shard: usize, quarantined: bool) {
        self.pool.set_quarantined(shard, quarantined);
    }

    /// Whether a `drain_and_exit` budget verdict asked the process to
    /// terminate. The engine itself never exits: it finishes flushing
    /// the current drain (every accepted request still gets a typed
    /// verdict) and leaves the actual nonzero exit to the caller.
    pub fn exit_requested(&self) -> bool {
        self.exit_requested
    }

    /// Serializable health snapshot: liveness/readiness, per-shard
    /// status, restart budgets, the fault and replay counters, and
    /// lease state. Read-only — taking it never quarantines, steals,
    /// or respawns (see [`HealthReport`] for the semantics).
    pub fn health(&self) -> HealthReport {
        let agg = self.aggregated_metrics();
        let (max_restarts, on_budget_exhausted) = match &self.supervisor {
            Some(sup) => {
                let sc = sup.config();
                (sc.max_restarts, sc.on_budget_exhausted.name())
            }
            None => (0, BudgetPolicy::Quarantine.name()),
        };
        let shards: Vec<ShardHealthRow> = match &self.supervisor {
            Some(sup) => sup
                .peek(&self.pool)
                .into_iter()
                .enumerate()
                .map(|(i, s)| ShardHealthRow {
                    shard: i,
                    health: s.health.name(),
                    heartbeat_age_ms: s.heartbeat_age.as_secs_f64() * 1e3,
                    depth: self.pool.depth(i),
                    quarantined: self.pool.is_quarantined(i),
                    quarantined_for_ms: s.quarantined_for.map(|d| d.as_secs_f64() * 1e3),
                    restarts_used: s.restarts_used,
                    restarts_remaining: max_restarts.saturating_sub(s.restarts_used),
                    backoff_pending: s.backoff_pending,
                })
                .collect(),
            // Unsupervised engines still report what the pool itself
            // knows: thread liveness and manual quarantines. Heartbeat
            // ages and restart budgets are watchdog concepts and read
            // as zero here.
            None => (0..self.pool.shard_count())
                .map(|i| ShardHealthRow {
                    shard: i,
                    health: if self.pool.shard_dead(i) {
                        ShardHealth::Dead.name()
                    } else {
                        ShardHealth::Healthy.name()
                    },
                    heartbeat_age_ms: 0.0,
                    depth: self.pool.depth(i),
                    quarantined: self.pool.is_quarantined(i),
                    quarantined_for_ms: None,
                    restarts_used: self.pool.restarts(i),
                    restarts_remaining: 0,
                    backoff_pending: false,
                })
                .collect(),
        };
        let any_serving = shards
            .iter()
            .any(|r| r.health != ShardHealth::Dead.name() && !r.quarantined);
        HealthReport {
            live: !self.exit_requested,
            ready: !self.exit_requested && any_serving,
            quarantined: self.pool.quarantined_count(),
            shards,
            supervised: self.supervisor.is_some(),
            max_restarts,
            on_budget_exhausted,
            exit_requested: self.exit_requested,
            degraded_permits: self.degraded_permits,
            degraded_in_use: self
                .degraded_permits
                .saturating_sub(self.degraded_gate.available()),
            replay: self.reliability.replay,
            retained_requests: self.replay_book.len(),
            panics_caught: agg.fault.panics_caught.get(),
            shard_restarts: agg.fault.shard_restarts.get(),
            watchdog_trips: agg.fault.watchdog_trips.get(),
            redirected_requests: agg.fault.redirected_requests.get(),
            degraded_requests: agg.fault.degraded_requests.get(),
            responses_lost: agg.fault.responses_lost.get(),
            replays: agg.reliability.replays.get(),
            replay_successes: agg.reliability.replay_successes.get(),
            replay_sheds: agg.reliability.replay_sheds.get(),
            gave_up: agg.reliability.gave_up.get(),
            leases: self
                .lease_stats()
                .map(|l| (l.served, l.revoked, l.chunks_lent)),
        }
    }

    /// The shared admission gate: route the request to the
    /// non-quarantined shard with the least estimated wait and apply
    /// the shed policy against the request's deadline. `Ok` =
    /// (destination shard, request, slack remaining in ns for a
    /// deadlined request); `Err` = a finished verdict — the counted
    /// [`Admission::Shed`] (request included), or
    /// [`Admission::Degraded`] when every shard is quarantined and the
    /// request was served inline. The slack rides along unrecorded:
    /// only [`accepted`](Self::accepted) samples it, so a `QueueFull`
    /// bounce-and-retry cannot double-count one request in the
    /// accepted-slack histogram.
    fn admission_gate(&mut self, req: Request) -> Result<(usize, Request, Option<u64>), Admission> {
        let now = Instant::now();
        // Route on the measured wait: each shard's depth × its live EMA
        // for this request's kernel class (the static knob is the EMA's
        // floor, so an unmeasured engine routes exactly as before).
        // Quarantined shards are not candidates; with the supervisor
        // off nothing is ever quarantined, so the filter is inert.
        let class = req.kernel.class();
        let routed = pick_shard_leased(
            self.shard_metrics
                .iter()
                .zip(self.pool.depths_iter())
                .enumerate()
                .filter(|(shard, _)| !self.pool.is_quarantined(*shard))
                .map(|(shard, (m, depth))| {
                    (
                        shard,
                        depth,
                        m.service_estimator.estimate_ns(class),
                        self.broker.as_ref().is_some_and(|b| b.is_leased(shard)),
                    )
                }),
        );
        let est_wait = match routed {
            Ok((_, wait)) => wait,
            // Inline execution starts immediately: no queue wait.
            Err(_) => Duration::ZERO,
        };
        if let Some(reason) = shed_decision(
            self.admission.shed,
            req.deadline,
            now,
            est_wait,
            self.pool.load_factor(),
        ) {
            let m = &self.admission_metrics.admission;
            m.shed_requests.inc();
            match reason {
                ShedReason::PastDeadline => m.shed_past_deadline.inc(),
                ShedReason::SlackExhausted => m.shed_slack_exhausted.inc(),
                ShedReason::Overload => m.shed_overload.inc(),
            }
            return Err(Admission::Shed { reason, request: req });
        }
        let slack_ns = req.deadline.slack_at(now).map(|s| s.as_nanos() as u64);
        match routed {
            Ok((shard, _)) => Ok((shard, req, slack_ns)),
            Err(_) => Err(self.degrade(req, slack_ns)),
        }
    }

    /// Bookkeeping for a request the pool definitely queued — this is
    /// the one place the accepted-slack histogram is fed.
    fn accepted(
        &mut self,
        shard: usize,
        parked: bool,
        slack_ns: Option<u64>,
        id: u64,
    ) -> Admission {
        self.in_flight.insert(self.next_seq, id);
        self.next_seq += 1;
        self.pending += 1;
        if let Some(slack) = slack_ns {
            self.admission_metrics.admission.slack_at_admission.record(slack);
        }
        Admission::Accepted { shard, parked }
    }

    /// Graceful degradation at the gate: every shard is quarantined, so
    /// serve the request inline (serial native execution) instead of
    /// refusing it. The response joins `collected` directly and comes
    /// back from the next drain in submission order like any other.
    fn degrade(&mut self, req: Request, slack_ns: Option<u64>) -> Admission {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending += 1;
        if let Some(slack) = slack_ns {
            self.admission_metrics.admission.slack_at_admission.record(slack);
        }
        self.serve_inline(Sequenced { seq, req });
        Admission::Degraded
    }

    /// Serial inline service for a request no shard can take: run the
    /// kernel on the calling thread, record completion on the engine's
    /// own metrics, and complete the sequence slot. Concurrent inline
    /// runs are bounded by the [`DegradedGate`] — the measured latency
    /// includes any wait for a permit, since that wait *is* part of the
    /// degraded service time.
    fn serve_inline(&mut self, sq: Sequenced) {
        let Sequenced { seq, req } = sq;
        let start = Instant::now();
        let sum =
            self.degraded_gate.run(|| run_native_kernel(req.kernel, &req.graph, req.source));
        let latency_ns = start.elapsed().as_nanos() as u64;
        self.admission_metrics.record_completion(
            req.kernel,
            Backend::Native,
            latency_ns,
            req.deadline,
            Instant::now(),
        );
        self.admission_metrics.fault.degraded_requests.inc();
        self.collect(
            seq,
            Response {
                id: req.id,
                backend: Backend::Native,
                result: RequestResult::Native(sum),
                latency_ns,
            },
        );
    }

    /// Deliver one response toward the current drain. With replay on,
    /// a failed response is first offered to the replay book: a
    /// re-submitted request keeps its sequence slot (and its in-flight
    /// entry) and produces no response here, while a successful one
    /// releases its retention. Everything terminal resolves the slot
    /// and joins `collected`. With replay off this is exactly the
    /// pre-HA remove-and-push.
    fn collect(&mut self, seq: u64, resp: Response) {
        if self.reliability.replay {
            if resp.result.is_ok() {
                if let Some(attempts) = self.replay_book.complete(seq) {
                    if attempts > 0 {
                        self.admission_metrics.reliability.replay_successes.inc();
                    }
                }
            } else if self.try_replay(seq) {
                return;
            }
        }
        self.in_flight.remove(&seq);
        self.collected.push((seq, resp));
    }

    /// Offer one failed sequence to the replay book. `true` = a replay
    /// was re-submitted and the failed response must *not* surface;
    /// `false` = the failure is terminal (deadline shed, budget
    /// exhausted, or never retained) and surfaces typed.
    fn try_replay(&mut self, seq: u64) -> bool {
        let rm = &self.admission_metrics;
        match self.replay_book.consider(seq, &self.reliability, Instant::now()) {
            ReplayVerdict::Replay { request, backoff } => {
                rm.reliability.replays.inc();
                if !backoff.is_zero() {
                    // Bounded by max_attempts doublings of the (small)
                    // backoff base and by the deadline slack, so the
                    // drain loop stalls at most a few milliseconds per
                    // replayed failure.
                    std::thread::sleep(backoff);
                }
                self.resubmit(seq, request);
                true
            }
            ReplayVerdict::Shed => {
                rm.reliability.replay_sheds.inc();
                false
            }
            ReplayVerdict::GaveUp => {
                rm.reliability.gave_up.inc();
                false
            }
            ReplayVerdict::NotRetained => false,
        }
    }

    /// Re-submit a replayed request under its original sequence
    /// number: healthiest live shard, inline fallback. Deliberately
    /// not counted as a redirect — the replay counters already account
    /// for it.
    fn resubmit(&mut self, seq: u64, req: Request) {
        let class = req.kernel.class();
        let retry = pick_shard_leased(
            self.shard_metrics
                .iter()
                .zip(self.pool.depths_iter())
                .enumerate()
                .filter(|(shard, _)| {
                    !self.pool.is_quarantined(*shard) && !self.pool.shard_dead(*shard)
                })
                .map(|(shard, (m, depth))| {
                    (
                        shard,
                        depth,
                        m.service_estimator.estimate_ns(class),
                        self.broker.as_ref().is_some_and(|b| b.is_leased(shard)),
                    )
                }),
        );
        let sq = Sequenced { seq, req };
        match retry {
            Ok((shard, _)) => {
                if let Err(bounced) = self.pool.try_submit_to(shard, sq) {
                    self.serve_inline(bounced);
                }
            }
            Err(_) => self.serve_inline(sq),
        }
    }

    /// Re-route an accepted-but-unprocessed request stolen from a
    /// quarantined shard: try the healthiest remaining shard, fall back
    /// to inline service. At-most-once is structural — the item was
    /// stolen from the queue *before* any consumer could pop it, so
    /// exactly one of {healthy shard, inline} executes it.
    fn reroute(&mut self, sq: Sequenced) {
        let class = sq.req.kernel.class();
        let retry = pick_shard_leased(
            self.shard_metrics
                .iter()
                .zip(self.pool.depths_iter())
                .enumerate()
                .filter(|(shard, _)| {
                    !self.pool.is_quarantined(*shard) && !self.pool.shard_dead(*shard)
                })
                .map(|(shard, (m, depth))| {
                    (
                        shard,
                        depth,
                        m.service_estimator.estimate_ns(class),
                        self.broker.as_ref().is_some_and(|b| b.is_leased(shard)),
                    )
                }),
        );
        match retry {
            Ok((shard, _)) => match self.pool.try_submit_to(shard, sq) {
                Ok(()) => self.admission_metrics.fault.redirected_requests.inc(),
                // The fallback shard is full: serve inline rather than
                // block the drain loop on a queue we are draining.
                Err(bounced) => self.serve_inline(bounced),
            },
            Err(_) => self.serve_inline(sq),
        }
    }

    /// One recovery pass, called when `drain` times out waiting with
    /// the supervisor enabled: classify shards, steal + re-route the
    /// queued work of quarantined ones, respawn dead ones, and — once
    /// the pool is provably idle for two consecutive passes — synthesize
    /// [`FaultKind::ResponseLost`] failures for sequences that can no
    /// longer be answered. Returns the updated idle-pass streak.
    fn recover(&mut self, idle_passes: u32) -> u32 {
        let verdict = self
            .supervisor
            .as_mut()
            .expect("recover is only called with a supervisor")
            .check(&self.pool);
        let fm = &self.admission_metrics.fault;
        fm.shard_restarts.add(verdict.restarted as u64);
        fm.watchdog_trips.add(verdict.trips as u64);
        for spent in &verdict.released {
            fm.quarantine_ns.record(spent.as_nanos() as u64);
        }
        for sq in verdict.redirected {
            self.reroute(sq);
        }
        if !verdict.budget_exhausted.is_empty() {
            self.apply_budget_policy(&verdict.budget_exhausted);
        }
        // Idle = nothing queued and nothing in processing anywhere
        // (depth decrements only after a batch's responses are sent),
        // so whatever is still unanswered can never arrive. Two
        // consecutive idle passes plus a final non-blocking sweep of
        // the channel close the race with a batch finishing between
        // the depth read and now.
        if self.pool.depths_iter().sum::<usize>() > 0 {
            return 0;
        }
        if idle_passes + 1 < 2 {
            return idle_passes + 1;
        }
        while let Ok((seq, resp)) = self.responses.try_recv() {
            self.collect(seq, resp);
        }
        // Re-check idleness: with replay on, a failure absorbed by the
        // sweep above may have just re-submitted its request — the pool
        // is busy again, and synthesizing its sequence as lost now
        // would answer it twice.
        if self.pool.depths_iter().sum::<usize>() == 0 && self.collected.len() < self.pending {
            self.synthesize_lost();
        }
        0
    }

    /// Apply `[supervisor] on_budget_exhausted` to shards the watchdog
    /// just reported dead with no restart credits left.
    ///
    /// * `Quarantine` (default) — nothing: the shard stays quarantined
    ///   and the engine keeps serving around it (the pre-HA behavior).
    /// * `DrainAndExit` — mark the engine for a nonzero process exit;
    ///   the current drain still flushes every accepted request with a
    ///   typed verdict before the CLI honors the flag.
    /// * `Rebuild` — reconstruct the dead shards once: respawn each on
    ///   its surviving queue with a zeroed restart count, a forgiven
    ///   watchdog history, and quarantine lifted. A second exhaustion
    ///   falls back to quarantine.
    fn apply_budget_policy(&mut self, exhausted: &[usize]) {
        let policy = self
            .supervisor
            .as_ref()
            .expect("budget policy implies a supervisor")
            .config()
            .on_budget_exhausted;
        match policy {
            BudgetPolicy::Quarantine => {}
            BudgetPolicy::DrainAndExit => {
                self.exit_requested = true;
            }
            BudgetPolicy::Rebuild => {
                if self.rebuilt {
                    return;
                }
                self.rebuilt = true;
                for &shard in exhausted {
                    if self.pool.respawn_shard(shard) {
                        self.pool.reset_restart_count(shard);
                        self.pool.set_quarantined(shard, false);
                        self.supervisor
                            .as_mut()
                            .expect("budget policy implies a supervisor")
                            .forgive(shard);
                        self.admission_metrics.fault.shard_restarts.inc();
                    }
                }
            }
        }
    }

    /// Answer every still-unanswered sequence with a typed
    /// [`FaultKind::ResponseLost`] failure — the no-drop invariant's
    /// last line of defense.
    fn synthesize_lost(&mut self) {
        // Snapshot first: with replay on, `collect` may re-submit an
        // orphan to the pool (keeping its in-flight entry) while this
        // loop runs.
        let orphans: Vec<(u64, u64)> =
            self.in_flight.iter().map(|(&seq, &id)| (seq, id)).collect();
        for (seq, id) in orphans {
            // The loss itself is a fault-layer fact and is always
            // counted, whether or not the reliability layer then
            // recovers the request by replaying it.
            self.admission_metrics.fault.responses_lost.inc();
            self.collect(
                seq,
                Response {
                    id,
                    backend: Backend::Native,
                    result: RequestResult::Failed(FaultKind::ResponseLost),
                    latency_ns: 0,
                },
            );
        }
    }

    /// Dispatch one request, blocking when the routed shard's channel
    /// is full (counted backpressure — PR 2's behavior, which
    /// [`ShedPolicy::Never`](super::admission::ShedPolicy::Never)
    /// preserves bit-for-bit since the gate then admits everything
    /// unconditionally).
    ///
    /// # Example
    ///
    /// ```
    /// use relic_smt::coordinator::{Deadline, Engine, EngineConfig, GraphKernel, Request};
    /// use relic_smt::graph::kronecker::paper_graph;
    /// use relic_smt::relic::PoolConfig;
    ///
    /// // One unpinned shard keeps the example portable — CI containers
    /// // may deny CPU-affinity calls.
    /// let mut engine = Engine::new(EngineConfig {
    ///     pool: PoolConfig { shards: Some(1), pin: false, ..PoolConfig::default() },
    ///     ..EngineConfig::default()
    /// });
    /// let verdict = engine.submit(Request {
    ///     id: 7,
    ///     kernel: GraphKernel::Tc,
    ///     graph: paper_graph(),
    ///     source: 0,
    ///     deadline: Deadline::none(),
    /// });
    /// assert!(verdict.is_accepted());
    /// let responses = engine.drain();
    /// assert_eq!(responses.len(), 1);
    /// assert_eq!(responses[0].id, 7);
    /// ```
    pub fn submit(&mut self, req: Request) -> Admission {
        let (shard, req, slack_ns) = match self.admission_gate(req) {
            Ok(routed) => routed,
            Err(verdict) => return verdict,
        };
        let id = req.id;
        if self.reliability.replays_kernel(req.kernel) {
            self.replay_book.retain(self.next_seq, &req);
        }
        self.pool.submit_to(shard, Sequenced { seq: self.next_seq, req });
        self.accepted(shard, false, slack_ns, id)
    }

    /// Non-blocking dispatch: a full channel returns
    /// [`Admission::QueueFull`] with the request instead of waiting, so
    /// an open-loop caller can retry, redirect, or drop it — the
    /// engine counts the rejection but takes no ownership.
    pub fn try_submit(&mut self, req: Request) -> Admission {
        let (shard, req, slack_ns) = match self.admission_gate(req) {
            Ok(routed) => routed,
            Err(verdict) => return verdict,
        };
        let id = req.id;
        if self.reliability.replays_kernel(req.kernel) {
            self.replay_book.retain(self.next_seq, &req);
        }
        match self.pool.try_submit_to(shard, Sequenced { seq: self.next_seq, req }) {
            Ok(()) => self.accepted(shard, false, slack_ns, id),
            Err(bounced) => {
                // Never queued: the caller keeps the request, so the
                // book must not hold a retention for this sequence.
                self.replay_book.forget(self.next_seq);
                self.admission_metrics.admission.queue_full_rejections.inc();
                Admission::QueueFull { rejected: bounced.req }
            }
        }
    }

    /// Dispatch with a parked producer: when the routed shard's channel
    /// is full, register on the shard's drain signal and sleep until
    /// its consumer frees capacity (no spinning, no lost wakeups — see
    /// [`crate::relic::pool`] for the protocol). Accepted requests
    /// report whether they had to park.
    ///
    /// If the shard's thread dies while the producer is parked, the
    /// pool reports it ([`crate::relic::ShardDead`]) instead of
    /// retrying forever: with the supervisor on the request is
    /// re-routed to a healthy shard (or served inline), with it off the
    /// dead shard is fatal — PR 5's semantics, now with a diagnosis
    /// instead of a hang.
    ///
    /// # Panics
    /// With supervision disabled, panics if the routed shard's thread
    /// is found dead while parked.
    pub fn submit_or_park(&mut self, req: Request) -> Admission {
        let (shard, req, slack_ns) = match self.admission_gate(req) {
            Ok(routed) => routed,
            Err(verdict) => return verdict,
        };
        let id = req.id;
        if self.reliability.replays_kernel(req.kernel) {
            self.replay_book.retain(self.next_seq, &req);
        }
        match self.pool.submit_or_park_to(shard, Sequenced { seq: self.next_seq, req }) {
            Ok(parked) => {
                if parked {
                    self.admission_metrics.admission.parked_submits.inc();
                }
                self.accepted(shard, parked, slack_ns, id)
            }
            Err(dead) => {
                assert!(
                    self.supervisor.is_some(),
                    "shard {} died with a parked producer waiting (supervision off)",
                    dead.shard
                );
                // Quarantine immediately — the next supervisor pass
                // classifies it properly and maybe respawns it — then
                // fall back for this request: another shard, or inline.
                self.pool.set_quarantined(dead.shard, true);
                self.admission_metrics.fault.watchdog_trips.inc();
                let sq = dead.item;
                let retry = pick_shard_leased(
                    self.shard_metrics
                        .iter()
                        .zip(self.pool.depths_iter())
                        .enumerate()
                        .filter(|(s, _)| !self.pool.is_quarantined(*s) && !self.pool.shard_dead(*s))
                        .map(|(s, (m, depth))| {
                            (
                                s,
                                depth,
                                m.service_estimator.estimate_ns(sq.req.kernel.class()),
                                self.broker.as_ref().is_some_and(|b| b.is_leased(s)),
                            )
                        }),
                );
                match retry {
                    Ok((other, _)) => {
                        self.pool.submit_to(other, sq);
                        self.admission_metrics.fault.redirected_requests.inc();
                        self.accepted(other, false, slack_ns, id)
                    }
                    Err(_) => self.degrade(sq.req, slack_ns),
                }
            }
        }
    }

    /// Wait for every response to the requests accepted since the last
    /// drain and return them **in submission order**. Shed and
    /// queue-full requests were never queued, so they are not waited
    /// for — the counters in [`Self::aggregated_metrics`] account for
    /// them.
    ///
    /// With the supervisor enabled, waiting never hangs on a fault:
    /// each timeout tick runs one recovery pass (quarantine, steal +
    /// re-route, respawn), and sequences that provably cannot be
    /// answered come back as [`RequestResult::Failed`].
    ///
    /// # Panics
    /// With supervision disabled only: panics if a shard thread dies
    /// while responses are outstanding — the alternative is waiting
    /// forever for responses the dead shard can no longer send.
    pub fn drain(&mut self) -> Vec<Response> {
        use std::sync::mpsc::RecvTimeoutError;
        // Tick fast enough that a tight `stuck_after` (tests, repro
        // sweeps) is honored promptly, but never busier than 20 Hz.
        let tick = match &self.supervisor {
            Some(sup) => (sup.config().stuck_after / 2)
                .clamp(Duration::from_millis(5), Duration::from_millis(50)),
            None => Duration::from_millis(50),
        };
        let mut idle_passes = 0u32;
        while self.collected.len() < self.pending {
            match self.responses.recv_timeout(tick) {
                Ok((seq, resp)) => {
                    idle_passes = 0;
                    self.collect(seq, resp);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.supervisor.is_some() {
                        idle_passes = self.recover(idle_passes);
                    } else {
                        let dead = self.pool.dead_shards();
                        assert!(
                            dead.is_empty(),
                            "engine shard(s) {dead:?} died with {} responses outstanding",
                            self.pending - self.collected.len()
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Every sender is gone. With the supervisor on this
                    // is a recovery path (answer what remains as lost);
                    // without it, the PR 5 hard failure.
                    if self.supervisor.is_some() {
                        self.synthesize_lost();
                    } else {
                        panic!(
                            "every engine shard died with {} responses outstanding",
                            self.pending - self.collected.len()
                        );
                    }
                }
            }
        }
        self.pending = 0;
        self.in_flight.clear();
        // A settled drain leaves nothing outstanding: any entry still
        // retained here was answered terminally (gave-up / shed / never
        // failed), so retention must not leak across drains.
        self.replay_book.clear();
        // Settle point: every completion of this drain has been
        // recorded, so re-select arms now — the next batch runs under
        // plans informed by everything measured so far. Shard threads
        // are idle between drains, so no request observes a mid-batch
        // arm switch.
        if let Some(tuner) = &self.tuner {
            tuner.tick();
        }
        let mut out = std::mem::take(&mut self.collected);
        out.sort_by_key(|(seq, _)| *seq);
        out.into_iter().map(|(_, resp)| resp).collect()
    }

    /// Drop-in replacement for [`Coordinator::process_batch`]: submit
    /// the whole batch (blocking admission), then drain. Responses come
    /// back in request order for every *accepted* request; under a shed
    /// policy the result can be shorter than the input (shed requests
    /// are counted, never silently missing).
    pub fn process_batch(&mut self, requests: Vec<Request>) -> Vec<Response> {
        for req in requests {
            // Verdict intentionally discarded: blocking admission never
            // returns QueueFull, and a Shed verdict is already counted
            // — batch callers read the shortfall from the metrics.
            let _ = self.submit(req);
        }
        self.drain()
    }

    /// Pool-level admission counters and per-shard occupancy.
    pub fn pool_snapshot(&self) -> PoolSnapshot {
        self.pool.snapshot()
    }

    /// Fraction of total admission-channel capacity in use right now.
    pub fn load_factor(&self) -> f32 {
        self.pool.load_factor()
    }

    /// Metrics of one shard's coordinator.
    pub fn shard_metrics(&self, shard: usize) -> &ServiceMetrics {
        &self.shard_metrics[shard]
    }

    /// Service-level metrics: every shard's [`ServiceMetrics`] plus the
    /// engine's admission-side counters folded into one aggregate.
    pub fn aggregated_metrics(&self) -> ServiceMetrics {
        let agg = ServiceMetrics::default();
        for m in &self.shard_metrics {
            agg.merge_from(m);
        }
        agg.merge_from(&self.admission_metrics);
        agg
    }

    /// Human-readable report: pool counters, the admission verdicts,
    /// the slack-at-admission distribution, the measured service-time
    /// EMAs (per shard and aggregated), the supervisor / fault-recovery
    /// counters (when active), one line per shard, and the aggregated
    /// service metrics.
    pub fn report(&self) -> String {
        let snap = self.pool.snapshot();
        let mut out = format!(
            "pool: {} shards, {} dispatched, {} backpressure stalls, {} parked\n",
            snap.shards, snap.dispatched, snap.backpressure_stalls, snap.parked_submits
        );
        let agg = self.aggregated_metrics();
        out += &format!(
            "admission: policy {}, {}\n",
            self.admission.shed.name(),
            agg.admission.summary()
        );
        // Slack and the estimator readout are always surfaced — an
        // operator tuning deadlines needs to see "nothing deadlined
        // yet" as much as the distribution itself.
        let slack = &agg.admission.slack_at_admission;
        out += &format!(
            "slack at admission: {}\n",
            if slack.count() > 0 {
                slack.summary("ns")
            } else {
                "(no deadlined requests admitted)".into()
            }
        );
        out += &format!(
            "service estimate: {} (floor {} µs{})\n",
            if self.admission.ema_alpha > 0.0 {
                format!("measured ema, alpha {:.2}", self.admission.ema_alpha)
            } else {
                "static knob (ema off)".into()
            },
            self.admission.service_estimate_ns / 1_000,
            if self.admission.edf { ", edf on" } else { "" },
        );
        if let Some(sup) = &self.supervisor {
            let sc = sup.config();
            out += &format!(
                "supervisor: on (stuck-after {:?}, restart budget {}), {} quarantined now\n",
                sc.stuck_after,
                sc.max_restarts,
                self.pool.quarantined_count()
            );
        }
        if let Some(ls) = self.lease_stats() {
            out += &format!(
                "cross-shard: leases served {}, revoked {}, chunks lent {}\n",
                ls.served, ls.revoked, ls.chunks_lent
            );
        }
        if !agg.fault.is_quiet() {
            out += &format!("faults: {}\n", agg.fault.summary());
        }
        if !agg.reliability.is_quiet() {
            out += &format!("reliability: {}\n", agg.reliability.summary());
        }
        if let Some(plan) = self.forced_plan {
            out += &format!("plan: forced {plan}\n");
        }
        if let Some(s) = &self.stream {
            out += &format!(
                "stream: {} batches, {} updates ({:.0}/s), {} parse errors, {} recomputes, \
                 stalls in/parse/analytics {}/{}/{}\n",
                s.batches,
                s.updates,
                s.updates_per_sec,
                s.parse_errors,
                s.recomputes,
                s.stalls[0],
                s.stalls[1],
                s.stalls[2],
            );
        }
        if let Some(tuner) = &self.tuner {
            out += &format!("tuner: on ({})\n", tuner.summary());
            for row in tuner.resolved() {
                out += &format!(
                    "  {} [{}]: {} ({} samples, mean {:.1} µs)\n",
                    row.kernel.artifact_name(),
                    shape_name(row.shape),
                    row.plan,
                    row.samples,
                    row.mean_ns as f64 / 1e3,
                );
            }
        }
        for (i, m) in self.shard_metrics.iter().enumerate() {
            let p = self.pool.placement(i);
            let cpus = match (p.main_cpu, p.assistant_cpu) {
                (Some(a), Some(b)) => format!("cpus {a}+{b}"),
                _ => "unpinned".into(),
            };
            out += &format!(
                "shard {i} [{cpus}]: {} reqs ({} pairs, {} intra), {} served, \
                 ema {}\n",
                m.native_requests.get(),
                m.relic_pairs.get(),
                m.intra_requests.get(),
                snap.occupancy[i],
                ema_summary(&m.service_estimator),
            );
        }
        out += &format!(
            "total: {} native reqs {}; ema {}\n",
            agg.native_requests.get(),
            agg.native_latency.summary("ns"),
            ema_summary(&agg.service_estimator),
        );
        out
    }
}

/// Per-kernel-class EMA readout for reports: `kernel=µs/samples` for
/// every measured class, or a placeholder while nothing (or no alpha)
/// has been measured.
fn ema_summary(estimator: &crate::metrics::ServiceEstimator) -> String {
    let mut parts = Vec::new();
    for kernel in super::GraphKernel::all() {
        let class = kernel.class();
        let n = estimator.samples(class);
        if n > 0 {
            parts.push(format!(
                "{}={:.1}µs/{n}",
                kernel.artifact_name(),
                estimator.estimate_ns(class) as f64 / 1e3,
            ));
        }
    }
    if parts.is_empty() {
        "(unmeasured)".into()
    } else {
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        run_native_kernel, Backend, Deadline, GraphKernel, RequestResult, ShedPolicy,
    };
    use crate::graph::kronecker::paper_graph;
    use crate::relic::FaultPlan;
    use std::time::Duration;

    fn engine(shards: usize) -> Engine {
        // Unpinned in tests: CI containers may refuse affinity calls.
        Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(shards), pin: false, ..PoolConfig::default() },
            ..EngineConfig::default()
        })
    }

    fn engine_with_admission(shards: usize, admission: AdmissionConfig) -> Engine {
        Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(shards), pin: false, ..PoolConfig::default() },
            admission,
            ..EngineConfig::default()
        })
    }

    /// Engine with a fault plan and a fast watchdog (tests should not
    /// wait out production timeouts).
    fn chaos_engine(shards: usize, fault: Arc<FaultPlan>) -> Engine {
        Engine::new(EngineConfig {
            pool: PoolConfig {
                shards: Some(shards),
                pin: false,
                fault: Some(fault),
                ..PoolConfig::default()
            },
            supervisor: SupervisorConfig {
                stuck_after: Duration::from_millis(40),
                ..SupervisorConfig::default()
            },
            ..EngineConfig::default()
        })
    }

    fn req(id: u64, kernel: GraphKernel) -> Request {
        Request {
            id,
            kernel,
            graph: paper_graph(),
            source: 0,
            deadline: Deadline::none(),
        }
    }

    fn req_due(id: u64, kernel: GraphKernel, deadline: Deadline) -> Request {
        Request { deadline, ..req(id, kernel) }
    }

    #[test]
    fn responses_in_submission_order_with_correct_checksums() {
        let mut e = engine(3);
        let kernels = GraphKernel::all();
        let expected: Vec<u64> =
            kernels.iter().map(|&k| run_native_kernel(k, &paper_graph(), 0)).collect();
        for round in 0..3 {
            for (i, &k) in kernels.iter().enumerate() {
                let verdict = e.submit(req((round * 10 + i) as u64, k));
                assert!(verdict.is_accepted(), "Never policy accepts everything");
            }
            let responses = e.drain();
            assert_eq!(responses.len(), kernels.len());
            for (i, r) in responses.iter().enumerate() {
                assert_eq!(r.id, (round * 10 + i) as u64, "submission order");
                assert_eq!(r.backend, Backend::Native);
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[i]),
                    "round {round} kernel {:?}",
                    kernels[i]
                );
            }
        }
    }

    #[test]
    fn single_shard_matches_single_pair_coordinator() {
        let mut single = Coordinator::with_parts(
            Router::new(RouterConfig::default(), None),
            None,
        );
        let mixed = |n: u64| -> Vec<Request> {
            (0..n).map(|i| req(i, GraphKernel::all()[i as usize % 6])).collect()
        };
        let reqs = mixed(7);
        let want = single.process_batch(mixed(7));
        let mut e = engine(1);
        let got = e.process_batch(reqs);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.backend, w.backend);
            assert_eq!(g.result, w.result);
        }
        assert_eq!(e.aggregated_metrics().native_requests.get(), 7);
    }

    #[test]
    fn metrics_aggregate_across_shards() {
        let mut e = engine(2);
        let n = 24;
        for i in 0..n {
            let _ = e.submit(req(i, GraphKernel::Tc));
        }
        let responses = e.drain();
        assert_eq!(responses.len(), n as usize);
        let agg = e.aggregated_metrics();
        assert_eq!(agg.native_requests.get(), n);
        assert_eq!(agg.native_latency.count(), n, "one latency sample per request");
        let snap = e.pool_snapshot();
        assert_eq!(snap.dispatched, n);
        assert_eq!(snap.occupancy.iter().sum::<u64>(), n);
        let report = e.report();
        assert!(report.contains("pool: 2 shards"));
        assert!(report.contains("admission: policy never"));
        assert!(report.contains("shard 0"));
        assert!(report.contains("total:"));
        // Supervision is on by default and nothing went wrong: the
        // supervisor line shows, the fault line stays silent.
        assert!(report.contains("supervisor: on"), "{report}");
        assert!(!report.contains("faults:"), "{report}");
        assert!(agg.fault.is_quiet());
    }

    #[test]
    fn empty_drain_is_fine() {
        let mut e = engine(2);
        assert!(e.drain().is_empty());
        assert!(e.process_batch(Vec::new()).is_empty());
    }

    #[test]
    fn try_submit_accepts_when_capacity_exists() {
        let mut e = engine(2);
        for i in 0..4 {
            // Channels are deep (64) and requests tiny: all accepted.
            let verdict = e.try_submit(req(i, GraphKernel::Bfs));
            assert!(verdict.is_accepted(), "request {i}");
            assert!(verdict.shard().is_some());
        }
        assert_eq!(e.drain().len(), 4);
        assert_eq!(e.aggregated_metrics().admission.queue_full_rejections.get(), 0);
    }

    #[test]
    fn past_deadline_policy_sheds_expired_requests_only() {
        let mut e = engine_with_admission(
            1,
            AdmissionConfig { shed: ShedPolicy::PastDeadline, ..Default::default() },
        );
        let expired = Deadline::at(Instant::now());
        let generous = Deadline::within(Duration::from_secs(3600));
        let verdict = e.submit(req_due(0, GraphKernel::Bfs, expired));
        assert_eq!(verdict.shed_reason(), Some(ShedReason::PastDeadline));
        assert!(matches!(verdict, Admission::Shed { request, .. } if request.id == 0),
            "the shed request comes back to the caller");
        assert!(e.submit(req_due(1, GraphKernel::Bfs, generous)).is_accepted());
        assert!(e.submit(req(2, GraphKernel::Bfs)).is_accepted(), "deadline-less never sheds");
        let responses = e.drain();
        assert_eq!(responses.len(), 2, "only accepted requests produce responses");
        assert_eq!(responses[0].id, 1);
        assert_eq!(responses[1].id, 2);
        let agg = e.aggregated_metrics();
        assert_eq!(agg.admission.shed_requests.get(), 1);
        assert_eq!(agg.admission.shed_past_deadline.get(), 1);
        assert_eq!(agg.admission.deadline_misses.get(), 0, "shed ≠ miss");
        // Reconciliation: submitted (3) = completed (2) + shed (1).
        assert_eq!(agg.native_requests.get() + agg.admission.shed_requests.get(), 3);
    }

    #[test]
    fn slack_exhausted_sheds_when_estimate_exceeds_deadline() {
        // A 10-second-per-request estimate makes any sub-second
        // deadline unmeetable even on an idle pool (the estimate
        // includes the request's own service time).
        let mut e = engine_with_admission(
            1,
            AdmissionConfig {
                shed: ShedPolicy::PastDeadline,
                service_estimate_ns: 10_000_000_000,
                ..Default::default()
            },
        );
        let deadline = Deadline::within(Duration::from_millis(100));
        let verdict = e.submit(req_due(0, GraphKernel::Bfs, deadline));
        assert_eq!(verdict.shed_reason(), Some(ShedReason::SlackExhausted));
        // A deadline beyond the estimate is admitted.
        assert!(e
            .submit(req_due(1, GraphKernel::Bfs, Deadline::within(Duration::from_secs(3600))))
            .is_accepted());
        assert_eq!(e.drain().len(), 1);
        assert_eq!(e.aggregated_metrics().admission.shed_slack_exhausted.get(), 1);
    }

    #[test]
    fn load_factor_policy_sheds_deadlined_requests_under_overload() {
        // A negative threshold reads as "always overloaded":
        // deterministic overload shedding without racing the shards.
        let mut e = engine_with_admission(
            2,
            AdmissionConfig { shed: ShedPolicy::LoadFactor(-1.0), ..Default::default() },
        );
        let generous = Deadline::within(Duration::from_secs(3600));
        let verdict = e.submit(req_due(0, GraphKernel::Bfs, generous));
        assert_eq!(verdict.shed_reason(), Some(ShedReason::Overload));
        // Deadline-less traffic rides through overload untouched.
        assert!(e.submit(req(1, GraphKernel::Bfs)).is_accepted());
        assert_eq!(e.drain().len(), 1);
        let agg = e.aggregated_metrics();
        assert_eq!(agg.admission.shed_overload.get(), 1);
        assert_eq!(agg.admission.shed_requests.get(), 1);
    }

    #[test]
    fn submit_or_park_accepts_and_reports_slack() {
        let mut e = engine(1);
        let verdict = e.submit_or_park(req_due(
            0,
            GraphKernel::Bfs,
            Deadline::within(Duration::from_secs(3600)),
        ));
        assert!(matches!(verdict, Admission::Accepted { parked: false, .. }),
            "an empty channel admits without parking");
        assert_eq!(e.drain().len(), 1);
        let agg = e.aggregated_metrics();
        assert_eq!(agg.admission.slack_at_admission.count(), 1);
        assert_eq!(agg.admission.parked_submits.get(), 0);
    }

    #[test]
    fn measured_ema_feeds_routing_and_report() {
        let mut e = engine_with_admission(
            2,
            AdmissionConfig { ema_alpha: 0.5, ..Default::default() },
        );
        let n = 12;
        for i in 0..n {
            assert!(e.submit(req(i, GraphKernel::Tc)).is_accepted());
        }
        assert_eq!(e.drain().len(), n as usize);
        let agg = e.aggregated_metrics();
        let est = &agg.service_estimator;
        assert!(est.is_measuring());
        assert_eq!(est.samples(GraphKernel::Tc.class()), n, "one EMA sample per request");
        assert!(est.estimate_ns(GraphKernel::Tc.class()) > 0);
        assert_eq!(est.samples(GraphKernel::Pr.class()), 0);
        let report = e.report();
        assert!(report.contains("measured ema, alpha 0.50"), "{report}");
        assert!(report.contains("tc="), "per-kernel readout present: {report}");
        // Routing still works after estimates become non-zero: a fresh
        // submit must land on *a* shard without panicking and drain.
        assert!(e.submit(req(99, GraphKernel::Tc)).is_accepted());
        assert_eq!(e.drain().len(), 1);
    }

    #[test]
    fn edf_engine_reconciles_and_reports() {
        use std::time::Duration;
        let mut e = engine_with_admission(
            1,
            AdmissionConfig { edf: true, ema_alpha: 0.25, ..Default::default() },
        );
        // Generous, *descending* deadlines: any multi-request batch the
        // shard drains is EDF-reordered, but nothing can miss or shed.
        let n = 10u64;
        for i in 0..n {
            let d = Deadline::within(Duration::from_secs(7200 - 60 * i));
            assert!(e.submit(req_due(i, GraphKernel::Bfs, d)).is_accepted());
        }
        let responses = e.drain();
        assert_eq!(responses.len(), n as usize, "no-drop invariant under EDF");
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64, "submission-order responses under EDF");
        }
        let agg = e.aggregated_metrics();
        assert_eq!(agg.native_requests.get(), n);
        assert_eq!(agg.admission.shed_requests.get(), 0);
        assert_eq!(agg.admission.deadline_misses.get(), 0);
        assert!(e.report().contains("edf on"), "report names the mode");
    }

    #[test]
    fn default_config_is_static_knob_fifo() {
        // The PR 4 degeneracy the acceptance criteria pin: defaults
        // carry no alpha and no EDF, so nothing measured, nothing
        // reordered.
        let d = AdmissionConfig::default();
        assert_eq!(d.ema_alpha, 0.0);
        assert!(!d.edf);
        let mut e = engine(2);
        for i in 0..8 {
            let _ = e.submit(req(i, GraphKernel::Sssp));
        }
        e.drain();
        let agg = e.aggregated_metrics();
        assert!(!agg.service_estimator.is_measuring());
        assert_eq!(agg.service_estimator.mean_estimate_ns(), 0);
        assert_eq!(agg.admission.edf_reorders.get(), 0);
        assert!(e.report().contains("static knob (ema off)"));
    }

    #[test]
    fn never_policy_reports_no_admission_activity() {
        let mut e = engine(1);
        for i in 0..6 {
            let _ = e.submit(req(i, GraphKernel::Cc));
        }
        e.drain();
        let agg = e.aggregated_metrics();
        assert_eq!(agg.admission.shed_requests.get(), 0);
        assert_eq!(agg.admission.parked_submits.get(), 0);
        assert_eq!(agg.admission.queue_full_rejections.get(), 0);
        assert_eq!(agg.admission.slack_at_admission.count(), 0);
        assert!(e.report().contains("shed=0"));
    }

    #[test]
    fn injected_kernel_panic_is_contained_end_to_end() {
        // Panic on the only TC request in the mix: exactly that request
        // fails, every other request completes, nothing is dropped, and
        // the engine keeps serving afterwards.
        let fault = Arc::new(FaultPlan::new().with_panic_on("tc", 1));
        let mut e = chaos_engine(2, fault);
        let kernels = [GraphKernel::Bfs, GraphKernel::Tc, GraphKernel::Bfs, GraphKernel::Cc];
        for (i, &k) in kernels.iter().enumerate() {
            assert!(e.submit(req(i as u64, k)).is_accepted());
        }
        let responses = e.drain();
        assert_eq!(responses.len(), 4, "no-drop invariant under a contained panic");
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64, "submission order preserved");
            if i == 1 {
                assert_eq!(r.result, RequestResult::Failed(FaultKind::Panic));
            } else {
                assert!(r.result.is_ok(), "request {i} unaffected: {:?}", r.result);
            }
        }
        let agg = e.aggregated_metrics();
        assert_eq!(agg.fault.panics_caught.get(), 1);
        // Reconciliation: submitted = completed + failed.
        assert_eq!(agg.native_requests.get(), 3);
        // The engine is still alive: a follow-up TC request succeeds
        // (the injection was one-shot).
        assert!(e.submit(req(9, GraphKernel::Tc)).is_accepted());
        let follow_up = e.drain();
        assert_eq!(follow_up.len(), 1);
        assert!(follow_up[0].result.is_ok());
    }

    #[test]
    fn all_shards_quarantined_degrades_to_inline_serial() {
        let mut e = engine(2);
        e.pool.set_quarantined(0, true);
        e.pool.set_quarantined(1, true);
        assert_eq!(e.quarantined_count(), 2);
        let expected = run_native_kernel(GraphKernel::Bfs, &paper_graph(), 0);
        let n = 3u64;
        for i in 0..n {
            let verdict = e.submit(req(i, GraphKernel::Bfs));
            assert!(verdict.is_degraded(), "all-quarantined serves inline");
            assert!(verdict.is_accepted(), "degraded still owes a response");
            assert_eq!(verdict.shard(), None);
        }
        let responses = e.drain();
        assert_eq!(responses.len(), n as usize);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.result, RequestResult::Native(expected), "checksum-equal to serial");
        }
        let agg = e.aggregated_metrics();
        assert_eq!(agg.fault.degraded_requests.get(), n);
        assert_eq!(agg.native_requests.get(), n, "degraded requests count as completions");
        assert!(e.report().contains("degraded=3"), "{}", e.report());
        // Releasing one shard restores normal routing.
        e.pool.set_quarantined(0, false);
        let verdict = e.submit(req(99, GraphKernel::Bfs));
        assert_eq!(verdict.shard(), Some(0));
        assert_eq!(e.drain().len(), 1);
    }

    #[test]
    fn killed_shard_is_respawned_and_every_request_answered() {
        // Kill shard 0's thread on its first batch. The batch is
        // requeued before the thread exits, the supervisor quarantines
        // + respawns, stolen work is re-routed, and every submitted
        // request still gets a successful response.
        let fault = Arc::new(FaultPlan::new().with_kill(0, 1));
        let mut e = chaos_engine(2, fault);
        let n = 8u64;
        let expected = run_native_kernel(GraphKernel::Bfs, &paper_graph(), 0);
        for i in 0..n {
            assert!(e.submit(req(i, GraphKernel::Bfs)).is_accepted());
        }
        let responses = e.drain();
        assert_eq!(responses.len(), n as usize, "no request lost to the kill");
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.result, RequestResult::Native(expected));
        }
        let agg = e.aggregated_metrics();
        assert!(agg.fault.shard_restarts.get() >= 1, "the dead shard was respawned");
        assert!(agg.fault.watchdog_trips.get() >= 1, "the watchdog tripped");
        // Follow-up traffic runs on the respawned pool.
        assert!(e.submit(req(100, GraphKernel::Bfs)).is_accepted());
        assert_eq!(e.drain().len(), 1);
    }

    #[test]
    fn dropped_response_is_synthesized_as_lost() {
        // Drop the first response on shard 0 (single shard: fully
        // deterministic). The drain's idle sweep must answer the
        // orphaned sequence with a typed ResponseLost failure instead
        // of hanging.
        let fault = Arc::new(FaultPlan::new().with_drop_response(0, 1));
        let mut e = chaos_engine(1, fault);
        for i in 0..3u64 {
            assert!(e.submit(req(i, GraphKernel::Bfs)).is_accepted());
        }
        let responses = e.drain();
        assert_eq!(responses.len(), 3, "no-drop even when a response is lost");
        let lost: Vec<u64> = responses
            .iter()
            .filter(|r| r.result == RequestResult::Failed(FaultKind::ResponseLost))
            .map(|r| r.id)
            .collect();
        assert_eq!(lost.len(), 1, "exactly the dropped response is synthesized");
        let agg = e.aggregated_metrics();
        assert_eq!(agg.fault.responses_lost.get(), 1);
        // The engine remains usable.
        assert!(e.submit(req(9, GraphKernel::Cc)).is_accepted());
        assert_eq!(e.drain().len(), 1);
    }

    #[test]
    #[should_panic(expected = "died")]
    fn supervisor_off_keeps_dead_shards_fatal() {
        // PR 5's failure semantics, pinned: with supervision disabled a
        // killed shard makes drain panic instead of recovering.
        let mut e = Engine::new(EngineConfig {
            pool: PoolConfig {
                shards: Some(1),
                pin: false,
                fault: Some(Arc::new(FaultPlan::new().with_kill(0, 1))),
                ..PoolConfig::default()
            },
            supervisor: SupervisorConfig { enabled: false, ..SupervisorConfig::default() },
            ..EngineConfig::default()
        });
        let _ = e.submit(req(0, GraphKernel::Bfs));
        let _ = e.drain();
    }

    /// Engine with cross-shard borrowing enabled.
    fn borrowing_engine(shards: usize, max_borrow: usize) -> Engine {
        Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(shards), pin: false, ..PoolConfig::default() },
            max_borrow,
            ..EngineConfig::default()
        })
    }

    #[test]
    fn max_borrow_zero_builds_no_broker() {
        // The degeneracy knob: the default engine has no lease broker at
        // all, so nothing on the data path can even consult one.
        let e = engine(2);
        assert!(e.lease_stats().is_none());
        let b = borrowing_engine(2, 1);
        assert_eq!(b.lease_stats(), Some(LeaseStats::default()));
    }

    #[test]
    fn borrowing_engine_answers_with_serial_checksums() {
        // Whale path end-to-end: single-request batches take the
        // odd-leftover fork-join, which under a broker opens a lease per
        // request. Whether or not a sibling attaches in time, the result
        // must be bitwise the serial checksum.
        let mut e = borrowing_engine(2, 1);
        let g = paper_graph();
        for (i, kernel) in GraphKernel::all().into_iter().enumerate() {
            assert!(e.submit(req(i as u64, kernel)).is_accepted());
            let responses = e.drain();
            assert_eq!(responses.len(), 1);
            assert_eq!(
                responses[0].result,
                RequestResult::Native(run_native_kernel(kernel, &g, 0)),
                "{kernel:?} under max_borrow=1 must match serial"
            );
        }
        // Teardown: dropping the engine closes the queues; the idle
        // hook's should_return sees the close and the shards exit.
        let report = e.report();
        assert!(report.contains("cross-shard: leases served"));
    }

    #[test]
    fn degraded_gate_bounds_concurrent_inline_runs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let gate = Arc::new(DegradedGate::new(2));
        let inflight = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let (gate, inflight, peak) =
                    (Arc::clone(&gate), Arc::clone(&inflight), Arc::clone(&peak));
                std::thread::spawn(move || {
                    gate.run(|| {
                        let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(5));
                        inflight.fetch_sub(1, Ordering::SeqCst);
                    });
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "at most two permits in flight");
        assert_eq!(inflight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn degraded_gate_releases_permit_on_panic() {
        let gate = DegradedGate::new(1);
        let _ = std::panic::catch_unwind(AssertUnwindSafe(|| gate.run(|| panic!("boom"))));
        // The permit came back: a second run does not deadlock.
        assert_eq!(gate.run(|| 7), 7);
    }

    #[test]
    fn tuned_engine_keeps_serial_checksums_and_reports_resolved_plans() {
        // The tuner explores the whole lattice across drains; every
        // response must still carry the serial checksum, and the report
        // must surface the resolved per-(kernel, shape) table.
        let mut e = Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            tuner: Some(TunerConfig { epsilon: 0.0, min_samples: 1, ..TunerConfig::default() }),
            ..EngineConfig::default()
        });
        let want: Vec<u64> = GraphKernel::all()
            .iter()
            .map(|&k| run_native_kernel(k, &paper_graph(), 0))
            .collect();
        for _ in 0..12 {
            let reqs: Vec<Request> = GraphKernel::all()
                .iter()
                .enumerate()
                .map(|(i, &k)| req(i as u64, k))
                .collect();
            let responses = e.process_batch(reqs);
            assert_eq!(responses.len(), 6);
            for (r, w) in responses.iter().zip(&want) {
                assert_eq!(r.result, RequestResult::Native(*w));
            }
        }
        let tuner = e.tuner().expect("tuner installed");
        let rows = tuner.resolved();
        assert_eq!(rows.len(), 6, "every kernel's paper-shape cell saw traffic");
        assert!(rows.iter().all(|r| r.samples >= 12), "completions fed every cell");
        let report = e.report();
        assert!(report.contains("tuner: on"), "report:\n{report}");
        assert!(report.contains("  tc [n<64]:"), "resolved table present:\n{report}");
    }

    #[test]
    fn forced_plan_engine_matches_serial_and_reports_the_plan() {
        use crate::relic::Schedule;
        let mut e = Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            plan: Some(crate::relic::ExecutionPlan::pair(Schedule::Dynamic)),
            ..EngineConfig::default()
        });
        let reqs: Vec<Request> = GraphKernel::all()
            .iter()
            .enumerate()
            .map(|(i, &k)| req(i as u64, k))
            .collect();
        let responses = e.process_batch(reqs);
        for (r, &k) in responses.iter().zip(GraphKernel::all().iter()) {
            assert_eq!(
                r.result,
                RequestResult::Native(run_native_kernel(k, &paper_graph(), 0)),
                "{k:?}"
            );
        }
        assert!(e.tuner().is_none(), "forced plan builds no tuner");
        assert!(e.report().contains("plan: forced pair:dynamic"), "{}", e.report());
    }

    #[test]
    fn default_config_builds_no_tuner_and_no_forced_plan() {
        // The degeneracy anchor: nothing plan-related exists unless
        // explicitly configured.
        let cfg = EngineConfig::default();
        assert!(cfg.tuner.is_none());
        assert!(cfg.plan.is_none());
        let e = engine(1);
        assert!(e.tuner().is_none());
        assert!(!e.report().contains("tuner"));
        assert!(!e.report().contains("plan: forced"));
    }

    #[test]
    fn degraded_engine_still_serves_with_gate() {
        // All shards quarantined → inline service through the gate; the
        // answer and the degraded counter are unchanged by the cap.
        let mut e = Engine::new(EngineConfig {
            pool: PoolConfig { shards: Some(1), pin: false, ..PoolConfig::default() },
            supervisor: SupervisorConfig {
                degraded_max_inflight: 1,
                ..SupervisorConfig::default()
            },
            ..EngineConfig::default()
        });
        e.set_quarantined(0, true);
        let verdict = e.submit(req(0, GraphKernel::Tc));
        assert!(matches!(verdict, Admission::Degraded));
        let responses = e.drain();
        assert_eq!(responses.len(), 1);
        assert_eq!(
            responses[0].result,
            RequestResult::Native(run_native_kernel(GraphKernel::Tc, &paper_graph(), 0))
        );
        assert_eq!(e.aggregated_metrics().fault.degraded_requests.get(), 1);
    }

    #[test]
    fn stream_counters_only_appear_when_attached() {
        // Degeneracy: with no snapshot attached the report is the PR 9
        // report, byte for byte; attaching adds exactly one line.
        let mut e = engine(1);
        let before = e.report();
        assert!(!before.contains("stream:"), "{before}");
        e.set_stream(Some(super::super::stream::StreamSnapshot {
            batches: 12,
            updates: 3400,
            updates_per_sec: 1.7e6,
            parse_errors: 1,
            recomputes: 3,
            stalls: [0, 4, 2],
        }));
        let after = e.report();
        assert!(
            after.contains("stream: 12 batches, 3400 updates (1700000/s), 1 parse errors"),
            "{after}"
        );
        assert!(after.contains("stalls in/parse/analytics 0/4/2"), "{after}");
        e.set_stream(None);
        assert_eq!(e.report(), before, "clearing restores the exact report");
    }
}
