//! The hybrid analytics coordinator — the deployment scenario the paper
//! motivates (§VI-A): a client-side graph-analytics service where
//! *coarse* work is offloaded to the AOT-compiled JAX/Pallas kernels
//! via PJRT ([`crate::runtime`]) while *fine-grained* requests are
//! paired onto the two logical threads of one SMT core through Relic.
//!
//! Components:
//! * [`router`] — per-request backend decision (PJRT vs native) based
//!   on kernel kind and graph size vs the artifact manifest, plus the
//!   least-wait shard pick ([`router::pick_shard`]);
//! * [`service`] — the request loop: batches compatible PJRT requests,
//!   pairs fine-grained native requests onto Relic, records latency and
//!   throughput metrics;
//! * [`admission`] — deadlines, the shed policy, the
//!   [`Admission`] verdict every engine submit path returns, and the
//!   [`edf_order`] earliest-deadline-first batch ordering rule;
//! * [`tuner`] — the online [`crate::relic::ExecutionPlan`] selector:
//!   epsilon-greedy per (kernel, graph-shape) cell over the candidate
//!   lattice, fed by measured completion latencies, optionally seeded
//!   by the probe/smtsim offline oracle;
//! * [`engine`] — the machine-scale layer: [`Engine::submit`] /
//!   [`Engine::try_submit`] / [`Engine::submit_or_park`] /
//!   [`Engine::drain`] over a [`crate::relic::RelicPool`] of pinned
//!   pair-shards, each shard running an unchanged single-pair
//!   [`Coordinator`] as its inner loop.
//!
//! See `examples/hybrid_pjrt.rs` for the end-to-end driver.

pub mod admission;
pub mod engine;
pub mod reliability;
pub mod router;
pub mod service;
pub mod stream;
pub mod tuner;

pub use admission::{
    edf_order, shed_decision, Admission, AdmissionConfig, Deadline, ShedPolicy, ShedReason,
};
pub use engine::{Engine, EngineConfig};
pub use reliability::{HealthReport, ReliabilityConfig, ReplayBook, ShardHealthRow};
pub use router::{pick_shard, pick_shard_leased, Backend, RouteError, Router, RouterConfig};
pub use service::{Coordinator, Request, RequestResult, Response, ServiceMetrics};
pub use stream::{EdgeDist, StreamConfig, StreamReport, StreamSnapshot};
pub use tuner::{ResolvedPlan, Tuner, TunerConfig};

use crate::graph::CsrGraph;

/// The graph kernels the service exposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GraphKernel {
    Bc,
    Bfs,
    Cc,
    Pr,
    Sssp,
    Tc,
}

impl GraphKernel {
    /// Manifest name of the kernel's PJRT artifact.
    pub fn artifact_name(self) -> &'static str {
        match self {
            GraphKernel::Bc => "bc",
            GraphKernel::Bfs => "bfs",
            GraphKernel::Cc => "cc",
            GraphKernel::Pr => "pagerank",
            GraphKernel::Sssp => "sssp",
            GraphKernel::Tc => "tc",
        }
    }

    /// Service-class index for [`crate::metrics::ServiceEstimator`]:
    /// a dense, stable `0..SERVICE_CLASSES` mapping (one EMA lane per
    /// kernel kind — service time varies far more across kernels than
    /// within one kernel at a fixed graph size).
    pub fn class(self) -> usize {
        match self {
            GraphKernel::Bc => 0,
            GraphKernel::Bfs => 1,
            GraphKernel::Cc => 2,
            GraphKernel::Pr => 3,
            GraphKernel::Sssp => 4,
            GraphKernel::Tc => 5,
        }
    }

    /// All kernels.
    pub fn all() -> [GraphKernel; 6] {
        [
            GraphKernel::Bc,
            GraphKernel::Bfs,
            GraphKernel::Cc,
            GraphKernel::Pr,
            GraphKernel::Sssp,
            GraphKernel::Tc,
        ]
    }

    /// The replay-safety contract: true when re-running this kernel
    /// with the same `(graph, source)` is guaranteed to produce the
    /// same checksum with no side effects, so the reliability layer's
    /// at-least-once replay may re-submit a failed request.
    ///
    /// All six GAP kernels qualify: each is a pure function of the
    /// immutable [`CsrGraph`] and the source vertex — no shared mutable
    /// state survives a request, deterministic iteration orders make
    /// the checksum reproducible bit-for-bit, and a request that failed
    /// mid-kernel left nothing behind (each run allocates its own
    /// frontier/score buffers). A future kernel that mutates the graph,
    /// consumes a stream, or reads wall-clock state MUST return `false`
    /// here; the replay layer then never re-submits it (its failures
    /// surface typed, exactly as with `replay = false`), and config
    /// validation rejects a `[reliability] replay_kernels` list that
    /// names it.
    pub fn idempotent(self) -> bool {
        match self {
            GraphKernel::Bc
            | GraphKernel::Bfs
            | GraphKernel::Cc
            | GraphKernel::Pr
            | GraphKernel::Sssp
            | GraphKernel::Tc => true,
        }
    }

    /// Parse from the CLI / figure name.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "bc" => GraphKernel::Bc,
            "bfs" => GraphKernel::Bfs,
            "cc" => GraphKernel::Cc,
            "pr" | "pagerank" => GraphKernel::Pr,
            "sssp" => GraphKernel::Sssp,
            "tc" => GraphKernel::Tc,
            _ => return None,
        })
    }
}

/// Run a kernel natively (serial, optimized) and reduce to a checksum.
pub fn run_native_kernel(kernel: GraphKernel, graph: &CsrGraph, source: u32) -> u64 {
    use crate::graph::*;
    use crate::probe::NoProbe;
    match kernel {
        GraphKernel::Bc => bc::checksum(&bc::brandes_single_source(graph, source, &mut NoProbe)),
        GraphKernel::Bfs => bfs::checksum(&bfs::bfs(graph, source, &mut NoProbe)),
        GraphKernel::Cc => cc::checksum(&cc::shiloach_vishkin(graph, &mut NoProbe)),
        GraphKernel::Pr => {
            pr::checksum(&pr::pagerank(graph, pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe))
        }
        GraphKernel::Sssp => sssp::checksum(&sssp::delta_stepping(
            graph,
            source,
            sssp::DEFAULT_DELTA,
            &mut NoProbe,
        )),
        GraphKernel::Tc => tc::checksum(tc::triangle_count(graph, &mut NoProbe)),
    }
}

/// Run a kernel with its hot loops split across the SMT pair (`par`),
/// reduced to the same checksum as [`run_native_kernel`] — the parallel
/// kernels are deterministic by construction, so the checksums agree.
pub fn run_native_kernel_par(
    kernel: GraphKernel,
    graph: &CsrGraph,
    source: u32,
    par: &crate::relic::Par,
) -> u64 {
    use crate::graph::*;
    match kernel {
        GraphKernel::Bc => bc::checksum(&bc::brandes_single_source_par(graph, source, par)),
        GraphKernel::Bfs => bfs::checksum(&bfs::bfs_par(graph, source, par)),
        GraphKernel::Cc => cc::checksum(&cc::shiloach_vishkin_par(graph, par)),
        GraphKernel::Pr => {
            pr::checksum(&pr::pagerank_par(graph, pr::MAX_ITERS, pr::TOLERANCE, par))
        }
        GraphKernel::Sssp => sssp::checksum(&sssp::delta_stepping_par(
            graph,
            source,
            sssp::DEFAULT_DELTA,
            par,
        )),
        GraphKernel::Tc => tc::checksum(tc::triangle_count_par(graph, par)),
    }
}

/// [`run_native_kernel_par`] under an explicit
/// [`ExecutionPlan`](crate::relic::ExecutionPlan): the plan decides
/// serial vs pair, the schedule, and the grain for the kernel's hot
/// loops. Plans change *assignment only* — for every plan the checksum
/// equals [`run_native_kernel`]'s (the tuner's correctness gate rests
/// on this).
pub fn run_native_kernel_plan(
    kernel: GraphKernel,
    graph: &CsrGraph,
    source: u32,
    par: &crate::relic::Par,
    plan: &crate::relic::ExecutionPlan,
) -> u64 {
    use crate::graph::*;
    match kernel {
        GraphKernel::Bc => {
            bc::checksum(&bc::brandes_single_source_plan(graph, source, par, plan))
        }
        GraphKernel::Bfs => bfs::checksum(&bfs::bfs_plan(graph, source, par, plan)),
        GraphKernel::Cc => cc::checksum(&cc::shiloach_vishkin_plan(graph, par, plan)),
        GraphKernel::Pr => {
            pr::checksum(&pr::pagerank_plan(graph, pr::MAX_ITERS, pr::TOLERANCE, par, plan))
        }
        GraphKernel::Sssp => sssp::checksum(&sssp::delta_stepping_plan(
            graph,
            source,
            sssp::DEFAULT_DELTA,
            par,
            plan,
        )),
        GraphKernel::Tc => tc::checksum(tc::triangle_count_plan(graph, par, plan)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_kernels_match_serial_checksums() {
        let g = crate::graph::kronecker::paper_graph();
        let relic = crate::relic::Relic::new();
        let par = crate::relic::Par::Relic(&relic);
        for k in GraphKernel::all() {
            assert_eq!(
                run_native_kernel_par(k, &g, 0, &par),
                run_native_kernel(k, &g, 0),
                "{k:?} parallel checksum must equal serial"
            );
        }
    }

    #[test]
    fn planned_kernels_match_serial_checksums_across_lattice() {
        let g = crate::graph::kronecker::paper_graph();
        let relic = crate::relic::Relic::new();
        let par = crate::relic::Par::Relic(&relic);
        for plan in crate::relic::ExecutionPlan::lattice() {
            for k in GraphKernel::all() {
                assert_eq!(
                    run_native_kernel_plan(k, &g, 0, &par, &plan),
                    run_native_kernel(k, &g, 0),
                    "{k:?} under plan {plan}"
                );
            }
        }
    }

    #[test]
    fn kernel_classes_are_dense_and_cover_service_classes() {
        // The estimator sizes its EMA lanes by this constant; every
        // kernel must map to a distinct in-range class.
        let mut seen = [false; crate::metrics::SERVICE_CLASSES];
        for k in GraphKernel::all() {
            let c = k.class();
            assert!(c < crate::metrics::SERVICE_CLASSES, "{k:?} class {c} out of range");
            assert!(!seen[c], "{k:?} shares class {c}");
            seen[c] = true;
        }
        assert!(seen.iter().all(|&s| s), "every class is used");
    }

    #[test]
    fn kernel_parse_roundtrip() {
        for k in GraphKernel::all() {
            let name = match k {
                GraphKernel::Pr => "pr",
                other => other.artifact_name(),
            };
            assert_eq!(GraphKernel::parse(name), Some(k));
        }
        assert_eq!(GraphKernel::parse("nope"), None);
    }

    #[test]
    fn native_kernels_run_on_paper_graph() {
        let g = crate::graph::kronecker::paper_graph();
        for k in GraphKernel::all() {
            let c = run_native_kernel(k, &g, 0);
            assert_eq!(c, run_native_kernel(k, &g, 0), "{k:?} deterministic");
        }
    }
}
