//! The coordinator service: batching, Relic pairing, PJRT dispatch,
//! and metrics.
//!
//! Request flow:
//! 1. [`Router`] assigns each request a backend.
//! 2. PJRT requests are grouped by (kernel, n) so each batch reuses the
//!    compiled executable and its dense-matrix packing buffers.
//! 3. Native requests are taken two at a time and co-scheduled on the
//!    SMT core via [`Relic::pair`] — the paper's fine-grained scenario;
//!    a leftover odd request runs with *intra-request* parallelism
//!    (its kernel's hot loops fork-joined over the same SMT pair via
//!    [`Par`]), so the assistant thread never idles through a batch.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::graph::{dense, CsrGraph};
use crate::metrics::{
    AdmissionMetrics, Counter, FaultMetrics, Histogram, ReliabilityMetrics, ServiceEstimator,
};
use crate::relic::{
    with_lease, CrossCtx, ExecutionPlan, FaultKind, FaultPlan, Par, ParMode, Relic, RelicConfig,
};
use crate::runtime::GraphExecutor;

use super::admission::{edf_order, Deadline};
use super::router::{Backend, Router};
use super::tuner::Tuner;
use super::{run_native_kernel, run_native_kernel_par, run_native_kernel_plan, GraphKernel};

/// One analytics request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub kernel: GraphKernel,
    pub graph: CsrGraph,
    pub source: u32,
    /// When this request stops being worth serving.
    /// [`Deadline::none()`] (the `Default`) opts out of deadline
    /// accounting and shedding entirely.
    pub deadline: Deadline,
}

/// Result payload of a processed request.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestResult {
    /// Checksum from the native kernel.
    Native(u64),
    /// Output vector from the PJRT kernel (scores, depths, …).
    Pjrt(Vec<f32>),
    /// The request did not complete; the typed cause says why (a
    /// contained kernel panic, a dead shard, a lost response). The
    /// no-drop invariant still holds: a failed request gets exactly
    /// one response, like any other.
    Failed(FaultKind),
}

impl RequestResult {
    /// True for any completed (non-failed) result.
    pub fn is_ok(&self) -> bool {
        !matches!(self, RequestResult::Failed(_))
    }
}

/// Response with latency/backends for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub backend: Backend,
    pub result: RequestResult,
    pub latency_ns: u64,
}

/// Service metrics snapshot (see [`Coordinator::report`]).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub native_requests: Counter,
    pub pjrt_requests: Counter,
    pub relic_pairs: Counter,
    /// Requests served with intra-request fork-join parallelism
    /// (the odd leftover of a native batch).
    pub intra_requests: Counter,
    pub native_latency: Histogram,
    pub pjrt_latency: Histogram,
    /// Admission-control counters. The engine records the
    /// admission-side events (shed, parked, slack) into its own
    /// instance; the coordinator records completion-side events
    /// (deadline misses, EDF reorders) per shard; aggregation merges
    /// both.
    pub admission: AdmissionMetrics,
    /// Measured per-kernel-class service times: an EMA fed one sample
    /// per completion (from the owning shard's thread only), read
    /// lock-free by the engine's router. Inert until the engine
    /// configures a non-zero `ema_alpha`.
    pub service_estimator: ServiceEstimator,
    /// Fault-isolation counters: the coordinator records contained
    /// kernel panics per shard; the engine records supervisor activity
    /// (restarts, redirects, quarantine time, degraded requests) into
    /// its own instance; aggregation merges both. All-zero in a
    /// healthy run.
    pub fault: FaultMetrics,
    /// At-least-once replay counters, recorded engine-side by the
    /// opt-in reliability layer. All-zero with `replay = false` (the
    /// default) — the degeneracy-ladder anchor.
    pub reliability: ReliabilityMetrics,
}

impl ServiceMetrics {
    /// Fold another instance into this one — the pool aggregates its
    /// per-shard metrics into a service-level view with this.
    pub fn merge_from(&self, other: &ServiceMetrics) {
        self.native_requests.add(other.native_requests.get());
        self.pjrt_requests.add(other.pjrt_requests.get());
        self.relic_pairs.add(other.relic_pairs.get());
        self.intra_requests.add(other.intra_requests.get());
        self.native_latency.merge_from(&other.native_latency);
        self.pjrt_latency.merge_from(&other.pjrt_latency);
        self.admission.merge_from(&other.admission);
        self.service_estimator.merge_from(&other.service_estimator);
        self.fault.merge_from(&other.fault);
        self.reliability.merge_from(&other.reliability);
    }

    /// Completion accounting for exactly one request: a request
    /// counter bump, one latency sample, one service-time EMA sample
    /// for the request's kernel class, and — when the request carried
    /// a deadline that `now` has passed — one deadline miss.
    ///
    /// Every execution path (PJRT, Relic-paired, odd-leftover
    /// intra-parallel, and the PJRT→native fallback) must fund the
    /// histograms through here: recording inline per-path is how the
    /// paired path once double-weighted solo requests and the
    /// intra-parallel path missed deadline accounting, and what keeps
    /// `Engine::report`'s per-shard aggregation meaningful is that
    /// "one completion = one sample" holds on every path. The same
    /// single-funnel rule is what makes the EMA trustworthy enough to
    /// route on.
    pub fn record_completion(
        &self,
        kernel: GraphKernel,
        backend: Backend,
        latency_ns: u64,
        deadline: Deadline,
        now: Instant,
    ) {
        match backend {
            Backend::Native => {
                self.native_requests.inc();
                self.native_latency.record(latency_ns);
            }
            Backend::Pjrt => {
                self.pjrt_requests.inc();
                self.pjrt_latency.record(latency_ns);
            }
        }
        self.service_estimator.record(kernel.class(), latency_ns);
        if deadline.is_past(now) {
            self.admission.deadline_misses.inc();
        }
    }
}

/// The hybrid analytics coordinator.
///
/// Metrics live behind an `Arc` so a pool shard's owner (the
/// [`super::Engine`] admission thread) can keep a handle and aggregate
/// across shards while each coordinator records from its own thread.
pub struct Coordinator {
    router: Router,
    executor: Option<GraphExecutor>,
    relic: Relic,
    /// Serve deadline-carrying requests earliest-deadline-first within
    /// each batch (see [`Coordinator::set_edf`]). Off by default.
    edf: bool,
    /// Deterministic fault injection (`None` = no faults). Consulted
    /// inside the containment wrapper, so an injected panic exercises
    /// exactly the path a real kernel panic takes.
    fault: Option<Arc<FaultPlan>>,
    /// Cross-shard borrowing context (`None` = PR 6 behavior exactly).
    /// With it set, the odd-leftover request opens a lease session so
    /// its intra-request fork-join can fan out to borrowed shards, and
    /// [`serve_lease`](Self::serve_lease) lets *this* shard lend its
    /// pair to a sibling's whale request while idle.
    cross: Option<CrossCtx>,
    /// Online plan selector shared across the engine's shards (`None` =
    /// pre-plan behavior exactly). With it set, native requests run
    /// under the tuner's current arm for their (kernel, shape) cell and
    /// feed their measured latency back ([`Tuner::record`]).
    tuner: Option<Arc<Tuner>>,
    /// A single forced [`ExecutionPlan`] for every native request
    /// (`--plan` on the CLI). Takes precedence over the tuner.
    forced_plan: Option<ExecutionPlan>,
    pub metrics: Arc<ServiceMetrics>,
}

impl Coordinator {
    /// Build from parts (router already configured against the
    /// manifest; `executor: None` → everything native).
    pub fn with_parts(router: Router, executor: Option<GraphExecutor>) -> Self {
        Self::with_config(router, executor, RelicConfig::default(), Arc::default())
    }

    /// Full-control constructor: explicit Relic configuration (a pool
    /// shard pins the assistant to its SMT sibling here) and a shared
    /// metrics handle.
    pub fn with_config(
        router: Router,
        executor: Option<GraphExecutor>,
        relic: RelicConfig,
        metrics: Arc<ServiceMetrics>,
    ) -> Self {
        Coordinator {
            router,
            executor,
            relic: Relic::with_config(relic),
            edf: false,
            fault: None,
            cross: None,
            tuner: None,
            forced_plan: None,
            metrics,
        }
    }

    /// Install (or clear) the shared online tuner. `None` — the default
    /// — keeps the native path bit-for-bit the pre-plan coordinator.
    pub fn set_tuner(&mut self, tuner: Option<Arc<Tuner>>) {
        self.tuner = tuner;
    }

    /// Force every native request onto one [`ExecutionPlan`] (`None` —
    /// the default — forces nothing). A forced plan wins over the
    /// tuner.
    pub fn set_plan(&mut self, plan: Option<ExecutionPlan>) {
        self.forced_plan = plan;
    }

    /// Install (or clear) the cross-shard borrowing context. `None` —
    /// the default — keeps every path bit-for-bit the single-pair
    /// coordinator; the engine sets this only when `max_borrow > 0`.
    pub fn set_cross(&mut self, cross: Option<CrossCtx>) {
        self.cross = cross;
    }

    /// Serve any cross-shard lease posted to this shard: attach and
    /// lend the pair to the owner's chunk race until the session closes
    /// or `should_return` fires. Called from the pool's idle hook —
    /// returns whether a lease was actually served.
    pub fn serve_lease(&self, should_return: &(dyn Fn() -> bool + Sync)) -> bool {
        match &self.cross {
            Some(ctx) => ctx.broker.serve(ctx.shard, &self.relic, should_return),
            None => false,
        }
    }

    /// Install (or clear) a deterministic fault-injection plan. `None`
    /// — the default — costs one branch per kernel execution.
    pub fn set_fault(&mut self, fault: Option<Arc<FaultPlan>>) {
        self.fault = fault;
    }

    /// Enable/disable earliest-deadline-first ordering within each
    /// processed batch ([`crate::coordinator::edf_order`]): deadlined
    /// requests run soonest-deadline-first, deadline-less requests keep
    /// their FIFO order among themselves (and a batch with no deadlines
    /// is processed bit-for-bit as with EDF off). Responses are still
    /// *returned* in request order — EDF moves queueing delay onto the
    /// requests with the most slack, it never drops or re-answers
    /// anything.
    pub fn set_edf(&mut self, edf: bool) {
        self.edf = edf;
    }

    /// Pre-compile every available PJRT executable so first-request
    /// latency excludes compilation (EXPERIMENTS.md §Perf iteration 3:
    /// p99 343 ms -> sub-ms on the serve demo).
    pub fn warmup(&mut self) {
        if let Some(exec) = self.executor.as_mut() {
            for (kernel, n) in exec.available() {
                if let Err(err) = exec.prepare(&kernel, n) {
                    eprintln!("warmup: {kernel}/{n}: {err:#}");
                }
            }
        }
    }

    /// Process a batch of requests, returning responses in request
    /// order. With [`set_edf`](Self::set_edf) enabled, the *processing*
    /// order of the native queue is [`edf_order`]; the response order
    /// is unchanged.
    pub fn process_batch(&mut self, requests: Vec<Request>) -> Vec<Response> {
        let mut responses: Vec<Option<Response>> = Vec::new();
        let mut native_queue: Vec<(usize, Request)> = Vec::new();
        let mut pjrt_queue: Vec<(usize, Request)> = Vec::new();

        for req in requests {
            let idx = responses.len();
            responses.push(None);
            match self.router.route(req.kernel, req.graph.num_vertices()) {
                Backend::Pjrt if self.executor.is_some() => pjrt_queue.push((idx, req)),
                _ => native_queue.push((idx, req)),
            }
        }

        // EDF: re-permute the native queue so the soonest deadlines run
        // first. `promoted[response idx]` marks deadlined requests that
        // moved *ahead* of their FIFO slot — if such a request then
        // completes on time, that is (an upper bound on) a miss the
        // reorder prevented, counted at its completion below. The Vec
        // stays empty (no allocation on the shard hot path) unless a
        // batch was actually reordered; `was_promoted` reads empty as
        // all-false.
        let mut promoted: Vec<bool> = Vec::new();
        if self.edf
            && native_queue.len() > 1
            && native_queue.iter().any(|(_, r)| !r.deadline.is_none())
        {
            let order = edf_order(native_queue.iter().map(|(_, r)| r.deadline));
            if order.iter().enumerate().any(|(pos, &from)| pos != from) {
                self.metrics.admission.edf_reorders.inc();
                promoted = vec![false; responses.len()];
                for (pos, &from) in order.iter().enumerate() {
                    let (ridx, req) = &native_queue[from];
                    if pos < from && !req.deadline.is_none() {
                        promoted[*ridx] = true;
                    }
                }
                let mut slots: Vec<Option<(usize, Request)>> =
                    native_queue.into_iter().map(Some).collect();
                native_queue = order
                    .iter()
                    .map(|&from| slots[from].take().expect("edf_order is a permutation"))
                    .collect();
            }
        }
        let was_promoted = |idx: usize| promoted.get(idx).copied().unwrap_or(false);

        // PJRT batches grouped by (kernel, n): executable + packing reuse.
        pjrt_queue.sort_by_key(|(_, r)| (r.kernel.artifact_name(), r.graph.num_vertices()));
        for (idx, req) in pjrt_queue {
            let t0 = Instant::now();
            let result = self.execute_pjrt(&req);
            let done = Instant::now();
            let latency = done.duration_since(t0).as_nanos() as u64;
            self.metrics.record_completion(req.kernel, Backend::Pjrt, latency, req.deadline, done);
            responses[idx] = Some(Response {
                id: req.id,
                backend: Backend::Pjrt,
                result,
                latency_ns: latency,
            });
        }

        // Plan-aware native path (ISSUE 9): taken only when a forced
        // plan or the online tuner is installed. Without either —
        // the default — the pre-plan pairing below runs bit-for-bit,
        // the degeneracy rung this PR preserves.
        if self.forced_plan.is_some() || self.tuner.is_some() {
            self.process_native_planned(native_queue, &mut responses, &promoted);
            return responses.into_iter().map(|r| r.expect("all requests answered")).collect();
        }

        // Native requests: pair onto the SMT core through Relic.
        //
        // Panic containment: every kernel execution runs inside
        // `catch_unwind`, *inside* the task closure handed to Relic —
        // a panicking kernel therefore still completes the pair / scope
        // protocol normally (the Relic machinery never sees the
        // unwind), and the poisoned request becomes a typed
        // `RequestResult::Failed(FaultKind::Panic)` response instead of
        // killing the shard thread. Fault injection fires inside the
        // same wrapper, so an injected panic takes exactly the real
        // panic's path.
        let plan = self.fault.clone();
        let contained = |kernel: GraphKernel, graph: &CsrGraph, source: u32| -> Result<u64, ()> {
            catch_unwind(AssertUnwindSafe(|| {
                if let Some(p) = plan.as_deref() {
                    if p.should_panic(kernel.artifact_name()) {
                        panic!("injected fault: panic on {}", kernel.artifact_name());
                    }
                }
                run_native_kernel(kernel, graph, source)
            }))
            .map_err(|_| ())
        };
        let mut iter = native_queue.into_iter();
        loop {
            match (iter.next(), iter.next()) {
                (Some((ia, ra)), Some((ib, rb))) => {
                    let t0 = Instant::now();
                    let out_a = AtomicU64::new(0);
                    let out_b = AtomicU64::new(0);
                    let fail_a = AtomicBool::new(false);
                    let fail_b = AtomicBool::new(false);
                    let task_b = || match contained(rb.kernel, &rb.graph, rb.source) {
                        Ok(sum) => out_b.store(sum, Ordering::Release),
                        Err(()) => fail_b.store(true, Ordering::Release),
                    };
                    self.relic.pair(
                        || match contained(ra.kernel, &ra.graph, ra.source) {
                            Ok(sum) => out_a.store(sum, Ordering::Release),
                            Err(()) => fail_a.store(true, Ordering::Release),
                        },
                        &task_b,
                    );
                    let done = Instant::now();
                    let latency = done.duration_since(t0).as_nanos() as u64;
                    self.metrics.relic_pairs.inc();
                    // One completion *per request*: the pair shares one
                    // wall-time measurement, but recording it once
                    // would weight a paired request half as much as a
                    // solo one and under-count the histogram — and each
                    // request's own deadline decides its miss. Failed
                    // requests skip the funnel: their "latency" is not
                    // a service-time sample and a panic is not a
                    // deadline miss.
                    for (idx, req, out, failed) in [
                        (ia, &ra, &out_a, &fail_a),
                        (ib, &rb, &out_b, &fail_b),
                    ] {
                        let result = if failed.load(Ordering::Acquire) {
                            self.metrics.fault.panics_caught.inc();
                            RequestResult::Failed(FaultKind::Panic)
                        } else {
                            self.metrics.record_completion(
                                req.kernel,
                                Backend::Native,
                                latency,
                                req.deadline,
                                done,
                            );
                            if was_promoted(idx) && !req.deadline.is_past(done) {
                                self.metrics.admission.deadline_misses_avoided.inc();
                            }
                            RequestResult::Native(out.load(Ordering::Acquire))
                        };
                        responses[idx] = Some(Response {
                            id: req.id,
                            backend: Backend::Native,
                            result,
                            latency_ns: latency,
                        });
                    }
                }
                (Some((idx, req)), None) => {
                    // Odd leftover: no partner request to pair with, so
                    // parallelize *inside* the request — fork-join the
                    // kernel's hot loops over the same SMT pair, and,
                    // with a cross context installed, over any idle
                    // shards a lease session can borrow (the whale
                    // path). The scope protocol re-raises an
                    // assistant-side panic on this thread *after* the
                    // chunk protocol completes, so catching here leaves
                    // the Relic pair healthy; the lease session
                    // likewise tears down before the unwind leaves it.
                    let t0 = Instant::now();
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        if let Some(p) = plan.as_deref() {
                            if p.should_panic(req.kernel.artifact_name()) {
                                panic!("injected fault: panic on {}", req.kernel.artifact_name());
                            }
                        }
                        match &self.cross {
                            Some(ctx) => {
                                with_lease(ctx, &self.relic, self.relic.default_schedule(), |par| {
                                    run_native_kernel_par(req.kernel, &req.graph, req.source, par)
                                })
                            }
                            None => run_native_kernel_par(
                                req.kernel,
                                &req.graph,
                                req.source,
                                &Par::Relic(&self.relic),
                            ),
                        }
                    }));
                    let done = Instant::now();
                    let latency = done.duration_since(t0).as_nanos() as u64;
                    let result = match outcome {
                        Ok(checksum) => {
                            self.metrics.intra_requests.inc();
                            self.metrics.record_completion(
                                req.kernel,
                                Backend::Native,
                                latency,
                                req.deadline,
                                done,
                            );
                            if was_promoted(idx) && !req.deadline.is_past(done) {
                                self.metrics.admission.deadline_misses_avoided.inc();
                            }
                            RequestResult::Native(checksum)
                        }
                        Err(_) => {
                            self.metrics.fault.panics_caught.inc();
                            RequestResult::Failed(FaultKind::Panic)
                        }
                    };
                    responses[idx] = Some(Response {
                        id: req.id,
                        backend: Backend::Native,
                        result,
                        latency_ns: latency,
                    });
                    break;
                }
                _ => break,
            }
        }

        responses.into_iter().map(|r| r.expect("all requests answered")).collect()
    }

    /// The plan-aware native path. Every request resolves an
    /// [`ExecutionPlan`] — the forced one, or the tuner's current arm
    /// for its (kernel, graph-shape) cell. Serial-mode requests are
    /// co-scheduled two at a time through [`Relic::pair`] exactly like
    /// the pre-plan path (plans decide *how a request runs*, and two
    /// serial requests still fill both SMT threads); pair-mode requests
    /// run one at a time with intra-request fork-join under the plan's
    /// schedule and grain, borrowing idle shards when the plan hints at
    /// it and a cross context exists. Measured completion latencies
    /// feed back to the sampled arm — the closed measurement loop.
    ///
    /// Containment, EDF promotion credit, and the one-completion-
    /// one-sample funnel all match the pre-plan path; failed requests
    /// never feed the tuner (a panic's "latency" is not a service-time
    /// sample).
    fn process_native_planned(
        &self,
        native_queue: Vec<(usize, Request)>,
        responses: &mut [Option<Response>],
        promoted: &[bool],
    ) {
        let was_promoted = |idx: usize| promoted.get(idx).copied().unwrap_or(false);
        let faults = self.fault.clone();
        let contained = |kernel: GraphKernel, graph: &CsrGraph, source: u32| -> Result<u64, ()> {
            catch_unwind(AssertUnwindSafe(|| {
                if let Some(p) = faults.as_deref() {
                    if p.should_panic(kernel.artifact_name()) {
                        panic!("injected fault: panic on {}", kernel.artifact_name());
                    }
                }
                run_native_kernel(kernel, graph, source)
            }))
            .map_err(|_| ())
        };
        let resolve = |req: &Request| -> (Option<usize>, ExecutionPlan) {
            match self.forced_plan {
                Some(plan) => (None, plan),
                None => {
                    let tuner = self.tuner.as_ref().expect("planned path needs a plan source");
                    let (arm, plan) = tuner.plan_for(req.kernel, req.graph.num_vertices());
                    (Some(arm), plan)
                }
            }
        };
        // Shared completion epilogue: funnel, promotion credit, tuner
        // feedback, response slot.
        let finish = |idx: usize,
                      req: &Request,
                      arm: Option<usize>,
                      outcome: Result<u64, ()>,
                      latency: u64,
                      done: Instant,
                      responses: &mut [Option<Response>]| {
            let result = match outcome {
                Ok(sum) => {
                    self.metrics.record_completion(
                        req.kernel,
                        Backend::Native,
                        latency,
                        req.deadline,
                        done,
                    );
                    if was_promoted(idx) && !req.deadline.is_past(done) {
                        self.metrics.admission.deadline_misses_avoided.inc();
                    }
                    if let (Some(tuner), Some(arm)) = (self.tuner.as_ref(), arm) {
                        tuner.record(req.kernel, req.graph.num_vertices(), arm, latency);
                    }
                    RequestResult::Native(sum)
                }
                Err(()) => {
                    self.metrics.fault.panics_caught.inc();
                    RequestResult::Failed(FaultKind::Panic)
                }
            };
            responses[idx] = Some(Response {
                id: req.id,
                backend: Backend::Native,
                result,
                latency_ns: latency,
            });
        };

        let mut pending: Option<(usize, Request, Option<usize>)> = None;
        for (idx, req) in native_queue {
            let (arm, plan) = resolve(&req);
            if plan.par_mode == ParMode::Serial {
                let Some((ia, ra, arm_a)) = pending.take() else {
                    pending = Some((idx, req, arm));
                    continue;
                };
                // Two serial-planned requests: co-schedule on the SMT
                // pair, exactly the pre-plan pairing.
                let t0 = Instant::now();
                let out_a = AtomicU64::new(0);
                let out_b = AtomicU64::new(0);
                let fail_a = AtomicBool::new(false);
                let fail_b = AtomicBool::new(false);
                let task_b = || match contained(req.kernel, &req.graph, req.source) {
                    Ok(sum) => out_b.store(sum, Ordering::Release),
                    Err(()) => fail_b.store(true, Ordering::Release),
                };
                self.relic.pair(
                    || match contained(ra.kernel, &ra.graph, ra.source) {
                        Ok(sum) => out_a.store(sum, Ordering::Release),
                        Err(()) => fail_a.store(true, Ordering::Release),
                    },
                    &task_b,
                );
                let done = Instant::now();
                let latency = done.duration_since(t0).as_nanos() as u64;
                self.metrics.relic_pairs.inc();
                for (i, r, a, out, failed) in
                    [(ia, &ra, arm_a, &out_a, &fail_a), (idx, &req, arm, &out_b, &fail_b)]
                {
                    let outcome = if failed.load(Ordering::Acquire) {
                        Err(())
                    } else {
                        Ok(out.load(Ordering::Acquire))
                    };
                    finish(i, r, a, outcome, latency, done, responses);
                }
            } else {
                // Pair-mode plan: intra-request fork-join under the
                // plan's schedule and grain (plus a lease session when
                // the plan hints at borrowing and a cross context
                // exists).
                let t0 = Instant::now();
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if let Some(p) = faults.as_deref() {
                        if p.should_panic(req.kernel.artifact_name()) {
                            panic!("injected fault: panic on {}", req.kernel.artifact_name());
                        }
                    }
                    match &self.cross {
                        Some(ctx) if plan.max_borrow_hint > 0 => {
                            with_lease(ctx, &self.relic, plan.schedule, |par| {
                                run_native_kernel_plan(
                                    req.kernel, &req.graph, req.source, par, &plan,
                                )
                            })
                        }
                        _ => run_native_kernel_plan(
                            req.kernel,
                            &req.graph,
                            req.source,
                            &Par::Relic(&self.relic),
                            &plan,
                        ),
                    }
                }))
                .map_err(|_| ());
                let done = Instant::now();
                let latency = done.duration_since(t0).as_nanos() as u64;
                if outcome.is_ok() {
                    self.metrics.intra_requests.inc();
                }
                finish(idx, &req, arm, outcome, latency, done, responses);
            }
        }
        // A lone serial-planned leftover runs on this thread alone —
        // the plan chose serial, so there is nothing to fork and no
        // partner left to pair with.
        if let Some((idx, req, arm)) = pending {
            let t0 = Instant::now();
            let outcome = contained(req.kernel, &req.graph, req.source);
            let done = Instant::now();
            let latency = done.duration_since(t0).as_nanos() as u64;
            finish(idx, &req, arm, outcome, latency, done, responses);
        }
    }

    fn execute_pjrt(&mut self, req: &Request) -> RequestResult {
        let exec = self.executor.as_mut().expect("routed to PJRT");
        let n = req.graph.num_vertices();
        let inputs: Vec<Vec<f32>> = match req.kernel {
            GraphKernel::Pr => {
                vec![dense::transition(&req.graph), dense::uniform(n)]
            }
            GraphKernel::Bfs => {
                vec![dense::adjacency(&req.graph), dense::one_hot(n, req.source)]
            }
            GraphKernel::Sssp => {
                vec![dense::weights_inf(&req.graph), dense::one_hot(n, req.source)]
            }
            GraphKernel::Cc => vec![dense::w0(&req.graph)],
            GraphKernel::Tc | GraphKernel::Bc => vec![dense::adjacency(&req.graph)],
        };
        match exec.execute(req.kernel.artifact_name(), n, &inputs) {
            Ok(values) => RequestResult::Pjrt(values),
            Err(err) => {
                // Fail soft: fall back to the native kernel and report.
                eprintln!("PJRT execution failed ({err:#}); falling back to native");
                RequestResult::Native(run_native_kernel(req.kernel, &req.graph, req.source))
            }
        }
    }

    /// Human-readable metrics report.
    pub fn report(&self) -> String {
        let mut out = format!(
            "native: {} reqs ({} relic pairs, {} intra-parallel) {}\npjrt:   {} reqs {}",
            self.metrics.native_requests.get(),
            self.metrics.relic_pairs.get(),
            self.metrics.intra_requests.get(),
            self.metrics.native_latency.summary("ns"),
            self.metrics.pjrt_requests.get(),
            self.metrics.pjrt_latency.summary("ns"),
        );
        let misses = self.metrics.admission.deadline_misses.get();
        if misses > 0 {
            out += &format!("\ndeadline misses: {misses}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RouterConfig;
    use crate::graph::kronecker::paper_graph;

    fn native_coordinator() -> Coordinator {
        Coordinator::with_parts(Router::new(RouterConfig::default(), None), None)
    }

    fn req(id: u64, kernel: GraphKernel) -> Request {
        Request {
            id,
            kernel,
            graph: paper_graph(),
            source: 0,
            deadline: Deadline::none(),
        }
    }

    #[test]
    fn processes_batch_in_order_with_pairing() {
        let mut c = native_coordinator();
        let reqs = (0..5).map(|i| req(i, GraphKernel::Tc)).collect();
        let responses = c.process_batch(reqs);
        assert_eq!(responses.len(), 5);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.backend, Backend::Native);
        }
        // 5 requests = 2 relic pairs + 1 intra-parallel leftover.
        assert_eq!(c.metrics.relic_pairs.get(), 2);
        assert_eq!(c.metrics.intra_requests.get(), 1);
        assert_eq!(c.metrics.native_requests.get(), 5);
        // One latency sample per request, paired or not.
        assert_eq!(c.metrics.native_latency.count(), 5);
        // All TC checksums identical (same graph).
        let first = &responses[0].result;
        assert!(responses.iter().all(|r| r.result == *first));
    }

    #[test]
    fn paired_results_match_serial_execution() {
        let mut c = native_coordinator();
        let serial: Vec<u64> = GraphKernel::all()
            .iter()
            .map(|&k| run_native_kernel(k, &paper_graph(), 0))
            .collect();
        let reqs = GraphKernel::all()
            .iter()
            .enumerate()
            .map(|(i, &k)| req(i as u64, k))
            .collect();
        let responses = c.process_batch(reqs);
        for (resp, want) in responses.iter().zip(&serial) {
            assert_eq!(resp.result, RequestResult::Native(*want));
        }
    }

    #[test]
    fn deadline_misses_recorded_on_every_native_path() {
        use std::time::Duration;
        // Already-expired deadlines: the paired path (requests 0+1) and
        // the odd intra-parallel leftover (request 2) must each record
        // exactly one miss — and one latency sample — per request.
        let mut c = native_coordinator();
        let mut reqs: Vec<Request> = (0..3).map(|i| req(i, GraphKernel::Bfs)).collect();
        for r in &mut reqs {
            r.deadline = Deadline::at(Instant::now());
        }
        let responses = c.process_batch(reqs);
        assert_eq!(responses.len(), 3);
        assert_eq!(c.metrics.admission.deadline_misses.get(), 3);
        assert_eq!(c.metrics.native_latency.count(), 3);
        assert!(c.report().contains("deadline misses: 3"));

        // Generous deadlines: no misses, and the report stays quiet.
        let mut c = native_coordinator();
        let mut reqs: Vec<Request> = (0..3).map(|i| req(i, GraphKernel::Bfs)).collect();
        for r in &mut reqs {
            r.deadline = Deadline::within(Duration::from_secs(3600));
        }
        c.process_batch(reqs);
        assert_eq!(c.metrics.admission.deadline_misses.get(), 0);
        assert!(!c.report().contains("deadline misses"));

        // No deadline at all: never a miss.
        let mut c = native_coordinator();
        c.process_batch(vec![req(0, GraphKernel::Bfs)]);
        assert_eq!(c.metrics.admission.deadline_misses.get(), 0);
    }

    #[test]
    fn edf_reorders_batches_and_counts_promotions() {
        use std::time::Duration;
        let mut c = native_coordinator();
        c.set_edf(true);
        // FIFO order is [loose, tight]: EDF must run tight first. Both
        // deadlines are generous, so the promoted request completes on
        // time and counts as an avoided miss (the counter's contract).
        let mut reqs: Vec<Request> = (0..2).map(|i| req(i, GraphKernel::Tc)).collect();
        reqs[0].deadline = Deadline::within(Duration::from_secs(7200));
        reqs[1].deadline = Deadline::within(Duration::from_secs(3600));
        let want = run_native_kernel(GraphKernel::Tc, &paper_graph(), 0);
        let responses = c.process_batch(reqs);
        // Responses stay in submission order with correct checksums.
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].id, 0);
        assert_eq!(responses[1].id, 1);
        for r in &responses {
            assert_eq!(r.result, RequestResult::Native(want));
        }
        assert_eq!(c.metrics.admission.edf_reorders.get(), 1);
        assert_eq!(c.metrics.admission.deadline_misses_avoided.get(), 1);
        assert_eq!(c.metrics.admission.deadline_misses.get(), 0);
        assert_eq!(c.metrics.native_requests.get(), 2);
    }

    #[test]
    fn edf_is_inert_without_deadlines_or_when_disabled() {
        use std::time::Duration;
        // Deadline-less traffic under EDF: the identity permutation —
        // no reorder recorded, same pairing structure as EDF off.
        let mut on = native_coordinator();
        on.set_edf(true);
        let mut off = native_coordinator();
        let mk = || (0..5).map(|i| req(i, GraphKernel::Bfs)).collect::<Vec<_>>();
        let got_on = on.process_batch(mk());
        let got_off = off.process_batch(mk());
        assert_eq!(on.metrics.admission.edf_reorders.get(), 0);
        assert_eq!(on.metrics.relic_pairs.get(), off.metrics.relic_pairs.get());
        assert_eq!(on.metrics.intra_requests.get(), off.metrics.intra_requests.get());
        for (a, b) in got_on.iter().zip(&got_off) {
            assert_eq!((a.id, &a.result), (b.id, &b.result));
        }
        // EDF disabled ignores deadline skew entirely.
        let mut c = native_coordinator();
        let mut reqs: Vec<Request> = (0..2).map(|i| req(i, GraphKernel::Cc)).collect();
        reqs[0].deadline = Deadline::within(Duration::from_secs(7200));
        reqs[1].deadline = Deadline::within(Duration::from_secs(3600));
        c.process_batch(reqs);
        assert_eq!(c.metrics.admission.edf_reorders.get(), 0);
        assert_eq!(c.metrics.admission.deadline_misses_avoided.get(), 0);
    }

    #[test]
    fn record_completion_feeds_the_service_estimator() {
        let c = native_coordinator();
        c.metrics.service_estimator.configure(0.5, 0);
        let mut c = c;
        let reqs = (0..4).map(|i| req(i, GraphKernel::Pr)).collect();
        c.process_batch(reqs);
        let est = &c.metrics.service_estimator;
        assert_eq!(est.samples(GraphKernel::Pr.class()), 4, "one EMA sample per request");
        assert!(est.estimate_ns(GraphKernel::Pr.class()) > 0, "measured a real latency");
        assert_eq!(est.samples(GraphKernel::Tc.class()), 0, "other classes untouched");
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut c = native_coordinator();
        assert!(c.process_batch(Vec::new()).is_empty());
        assert_eq!(c.metrics.intra_requests.get(), 0);
    }

    #[test]
    fn injected_panic_is_contained_in_the_paired_path() {
        // 4 requests = 2 relic pairs; the lone TC request (id 1, paired
        // with id 0) panics — targeting the only TC keeps the trip
        // deterministic even though pair members run concurrently. The
        // batch must still answer all 4, the partner's checksum must be
        // untouched, and the panic is counted — not propagated.
        let mut c = native_coordinator();
        c.set_fault(Some(Arc::new(FaultPlan::new().with_panic_on("tc", 1))));
        let want = run_native_kernel(GraphKernel::Bfs, &paper_graph(), 0);
        let kernels = [GraphKernel::Bfs, GraphKernel::Tc, GraphKernel::Bfs, GraphKernel::Bfs];
        let responses = c.process_batch(
            kernels.iter().enumerate().map(|(i, &k)| req(i as u64, k)).collect(),
        );
        assert_eq!(responses.len(), 4);
        let failed: Vec<u64> = responses
            .iter()
            .filter(|r| !r.result.is_ok())
            .map(|r| r.id)
            .collect();
        assert_eq!(failed, vec![1], "exactly the poisoned request failed");
        assert_eq!(responses[1].result, RequestResult::Failed(FaultKind::Panic));
        for r in responses.iter().filter(|r| r.result.is_ok()) {
            assert_eq!(r.result, RequestResult::Native(want), "partners unharmed");
        }
        assert_eq!(c.metrics.fault.panics_caught.get(), 1);
        // Failed requests skip the completion funnel.
        assert_eq!(c.metrics.native_requests.get(), 3);
        assert_eq!(c.metrics.native_latency.count(), 3);
        assert_eq!(c.metrics.relic_pairs.get(), 2);
        // The pair survives: a follow-up batch works normally.
        let again = c.process_batch(vec![req(9, GraphKernel::Bfs)]);
        assert_eq!(again[0].result, RequestResult::Native(want));
    }

    #[test]
    fn injected_panic_is_contained_in_the_intra_parallel_path() {
        // A batch of one forces the odd-leftover fork-join path.
        let mut c = native_coordinator();
        c.set_fault(Some(Arc::new(FaultPlan::new().with_panic_on("tc", 1))));
        let responses = c.process_batch(vec![req(0, GraphKernel::Tc)]);
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].result, RequestResult::Failed(FaultKind::Panic));
        assert_eq!(c.metrics.fault.panics_caught.get(), 1);
        assert_eq!(c.metrics.intra_requests.get(), 0, "failures are not completions");
        // The relic pair still works for the next request.
        let want = run_native_kernel(GraphKernel::Tc, &paper_graph(), 0);
        let again = c.process_batch(vec![req(1, GraphKernel::Tc)]);
        assert_eq!(again[0].result, RequestResult::Native(want));
        assert_eq!(c.metrics.intra_requests.get(), 1);
    }

    #[test]
    fn cross_ctx_with_zero_borrow_matches_plain_coordinator() {
        // The degeneracy rung for PR 7: max_borrow = 0 must leave the
        // odd-leftover path bit-for-bit the single-pair coordinator.
        use crate::relic::LeaseBroker;
        let mut plain = native_coordinator();
        let mut crossed = native_coordinator();
        crossed.set_cross(Some(CrossCtx {
            broker: Arc::new(LeaseBroker::new(1)),
            shard: 0,
            max_borrow: 0,
            offer_depth: 0,
        }));
        for k in GraphKernel::all() {
            let a = plain.process_batch(vec![req(0, k)]);
            let b = crossed.process_batch(vec![req(0, k)]);
            assert_eq!(a[0].result, b[0].result, "{k:?}");
        }
        assert!(!plain.serve_lease(&|| false), "no cross context → nothing to serve");
        assert!(!crossed.serve_lease(&|| false), "no lease posted → nothing served");
    }

    #[test]
    fn no_fault_plan_changes_nothing() {
        // A coordinator with no plan (and one with an empty plan) is
        // bit-for-bit the degenerate PR 5 coordinator.
        let mut plain = native_coordinator();
        let mut empty = native_coordinator();
        empty.set_fault(Some(Arc::new(FaultPlan::new())));
        let a = plain.process_batch((0..5).map(|i| req(i, GraphKernel::Pr)).collect());
        let b = empty.process_batch((0..5).map(|i| req(i, GraphKernel::Pr)).collect());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, &x.result), (y.id, &y.result));
        }
        assert!(plain.metrics.fault.is_quiet());
        assert!(empty.metrics.fault.is_quiet());
    }

    #[test]
    fn forced_serial_plan_pairs_requests_and_runs_the_leftover_inline() {
        // A forced serial plan reproduces the pre-plan pairing for the
        // paired positions, but the odd leftover now honors the plan
        // and runs serially (no intra-request fork-join).
        let mut c = native_coordinator();
        c.set_plan(Some(ExecutionPlan::serial()));
        let want = run_native_kernel(GraphKernel::Tc, &paper_graph(), 0);
        let responses = c.process_batch((0..5).map(|i| req(i, GraphKernel::Tc)).collect());
        assert_eq!(responses.len(), 5);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.result, RequestResult::Native(want));
        }
        assert_eq!(c.metrics.relic_pairs.get(), 2);
        assert_eq!(c.metrics.intra_requests.get(), 0, "serial plan never forks");
        assert_eq!(c.metrics.native_requests.get(), 5);
        assert_eq!(c.metrics.native_latency.count(), 5);
    }

    #[test]
    fn forced_pair_plans_run_every_request_intra_with_serial_checksums() {
        use crate::relic::Schedule;
        for schedule in Schedule::all() {
            let mut c = native_coordinator();
            c.set_plan(Some(ExecutionPlan::pair(schedule).with_grain(4)));
            let serial: Vec<u64> = GraphKernel::all()
                .iter()
                .map(|&k| run_native_kernel(k, &paper_graph(), 0))
                .collect();
            let reqs = GraphKernel::all()
                .iter()
                .enumerate()
                .map(|(i, &k)| req(i as u64, k))
                .collect();
            let responses = c.process_batch(reqs);
            for (resp, want) in responses.iter().zip(&serial) {
                assert_eq!(resp.result, RequestResult::Native(*want), "{schedule:?}");
            }
            assert_eq!(c.metrics.relic_pairs.get(), 0, "{schedule:?}: no inter-pairing");
            assert_eq!(c.metrics.intra_requests.get(), 6, "{schedule:?}");
            assert_eq!(c.metrics.native_latency.count(), 6, "{schedule:?}");
        }
    }

    #[test]
    fn tuner_feeds_on_completions_and_keeps_checksums_serial() {
        use crate::coordinator::tuner::{Tuner, TunerConfig};
        let mut c = native_coordinator();
        let tuner = Arc::new(Tuner::new(TunerConfig {
            epsilon: 0.0,
            min_samples: 1,
            ..TunerConfig::default()
        }));
        c.set_tuner(Some(tuner.clone()));
        let want = run_native_kernel(GraphKernel::Pr, &paper_graph(), 0);
        // Enough batches to sweep the whole lattice for this cell.
        for round in 0..(2 * tuner.lattice().len() as u64) {
            let responses =
                c.process_batch((0..2).map(|i| req(round * 2 + i, GraphKernel::Pr)).collect());
            for r in &responses {
                assert_eq!(r.result, RequestResult::Native(want), "round {round}");
            }
            tuner.tick();
        }
        let rows = tuner.resolved();
        assert_eq!(rows.len(), 1, "exactly the (Pr, paper-shape) cell saw traffic");
        assert!(rows[0].samples > 0, "completions fed the tuner");
        assert_eq!(rows[0].kernel, GraphKernel::Pr);
        // One completion sample per request on every planned path too.
        assert_eq!(
            c.metrics.native_latency.count(),
            4 * tuner.lattice().len() as u64
        );
    }

    #[test]
    fn injected_panic_is_contained_under_a_forced_plan() {
        for plan in [ExecutionPlan::serial(), ExecutionPlan::default()] {
            let mut c = native_coordinator();
            c.set_plan(Some(plan));
            c.set_fault(Some(Arc::new(FaultPlan::new().with_panic_on("tc", 1))));
            let want = run_native_kernel(GraphKernel::Bfs, &paper_graph(), 0);
            let kernels = [GraphKernel::Tc, GraphKernel::Bfs];
            let responses = c.process_batch(
                kernels.iter().enumerate().map(|(i, &k)| req(i as u64, k)).collect(),
            );
            assert_eq!(responses[0].result, RequestResult::Failed(FaultKind::Panic), "{plan}");
            assert_eq!(responses[1].result, RequestResult::Native(want), "{plan}");
            assert_eq!(c.metrics.fault.panics_caught.get(), 1, "{plan}");
            // Failed requests skip the completion funnel here too.
            assert_eq!(c.metrics.native_requests.get(), 1, "{plan}");
            // The shard survives for the next batch.
            let again = c.process_batch(vec![req(9, GraphKernel::Bfs)]);
            assert_eq!(again[0].result, RequestResult::Native(want), "{plan}");
        }
    }

    #[test]
    fn odd_leftover_checksum_matches_serial_for_every_kernel() {
        // A batch of one forces the intra-parallel path; its checksum
        // must equal the plain serial kernel's.
        for k in GraphKernel::all() {
            let mut c = native_coordinator();
            let want = run_native_kernel(k, &paper_graph(), 0);
            let responses = c.process_batch(vec![req(7, k)]);
            assert_eq!(responses.len(), 1);
            assert_eq!(responses[0].result, RequestResult::Native(want), "{k:?}");
            assert_eq!(c.metrics.intra_requests.get(), 1);
            assert_eq!(c.metrics.relic_pairs.get(), 0);
        }
    }
}
