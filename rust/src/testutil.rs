//! Deterministic PRNG + a small property-testing helper.
//!
//! The offline environment carries no `rand`/`proptest`, so the crate
//! ships its own xorshift-based generator (used by the Kronecker graph
//! generator, workload builders, and property tests) and a minimal
//! property harness with input reporting on failure.

/// xorshift64* — fast, deterministic, good enough for workload generation
/// and property tests (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator; `seed` is perturbed so 0 is a valid seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`; `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift trick (Lemire); bias negligible for our bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A clone of this generator fast-forwarded by `steps` draws, in
    /// O(64² · log steps) bit operations instead of O(steps).
    ///
    /// The xorshift64 state transition is linear over GF(2) (the `*`
    /// output multiplier perturbs each draw, not the state), so
    /// advancing N draws is applying the N-th power of the 64×64 step
    /// matrix. This is what lets the Kronecker generator hand each
    /// chunk of edge indices its exact position in the serial stream —
    /// parallel generation stays bit-identical to the serial one.
    pub fn jumped(&self, steps: u64) -> Rng {
        Rng { state: jump_state(self.state, steps) }
    }
}

/// One xorshift64 state transition (the linear part of [`Rng::next_u64`]).
#[inline]
fn xorshift_step(mut x: u64) -> u64 {
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    x
}

/// Apply a GF(2) linear map (columns = images of basis vectors) to `x`.
#[inline]
fn mat_apply(m: &[u64; 64], mut x: u64) -> u64 {
    let mut out = 0;
    while x != 0 {
        out ^= m[x.trailing_zeros() as usize];
        x &= x - 1;
    }
    out
}

/// Compose two GF(2) linear maps: `(a ∘ b)(x) = a(b(x))`.
fn mat_mul(a: &[u64; 64], b: &[u64; 64]) -> [u64; 64] {
    std::array::from_fn(|i| mat_apply(a, b[i]))
}

/// State after `steps` xorshift64 transitions, via square-and-multiply
/// on the step matrix.
fn jump_state(state: u64, steps: u64) -> u64 {
    let mut m: [u64; 64] = std::array::from_fn(|i| xorshift_step(1u64 << i));
    let mut acc: [u64; 64] = std::array::from_fn(|i| 1u64 << i);
    let mut k = steps;
    while k != 0 {
        if k & 1 == 1 {
            acc = mat_mul(&m, &acc);
        }
        m = mat_mul(&m, &m);
        k >>= 1;
    }
    mat_apply(&acc, state)
}

/// Run `f` over `cases` deterministic random seeds; on panic or `Err`,
/// report the failing seed so the case can be replayed.
pub fn check<F: Fn(&mut Rng) -> std::result::Result<(), String>>(cases: u32, f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ (case as u64) << 17);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {case} (seed 0xC0FFEE^({case}<<17)): {msg}");
        }
    }
}

/// Assert two floats are close; returns Err for use inside [`check`].
pub fn close(a: f64, b: f64, tol: f64) -> std::result::Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let (mut a, mut b) = (Rng::new(7), Rng::new(7));
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(11);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn jumped_matches_sequential_stepping() {
        for (seed, steps) in [(1u64, 0u64), (7, 1), (7, 2), (42, 63), (42, 1000), (9, 123_457)] {
            let mut stepped = Rng::new(seed);
            for _ in 0..steps {
                stepped.next_u64();
            }
            let mut jumped = Rng::new(seed).jumped(steps);
            for i in 0..16 {
                assert_eq!(
                    stepped.next_u64(),
                    jumped.next_u64(),
                    "seed {seed} steps {steps} draw {i}"
                );
            }
        }
    }

    #[test]
    fn jumps_compose_additively() {
        let base = Rng::new(0xDEAD);
        let mut once = base.jumped(1500);
        let mut twice = base.jumped(1000).jumped(500);
        for _ in 0..8 {
            assert_eq!(once.next_u64(), twice.next_u64());
        }
    }

    #[test]
    fn chance_rate_sane() {
        let mut rng = Rng::new(9);
        let hits = (0..100_000).filter(|_| rng.chance(0.25)).count();
        assert!((20_000..30_000).contains(&hits), "hits={hits}");
    }
}
