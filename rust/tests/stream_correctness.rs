//! Streaming correctness suite.
//!
//! The tentpole invariants of the edge-stream pipeline and the
//! incremental kernels behind it:
//!
//! 1. **Differential** — at *every* checkpoint of a randomized seeded
//!    edge-insertion stream (power-law and uniform), incremental CC,
//!    delta-PageRank and dynamic BFS equal a full recompute on the
//!    rebuilt graph: CC/BFS against [`oracle`], PageRank bitwise
//!    against the serial kernel.
//! 2. **Determinism** — the same seed produces bitwise-identical
//!    emitted lines, checksums and scores across two pipeline runs.
//! 3. **No drop, no reorder** — under a deliberately tiny stage queue
//!    the pipeline backpressures; every input document still produces
//!    exactly one emit line, in input order.
//! 4. **Degeneracy** — with `[stream]` off the engine is
//!    response-for-response (and report-for-report) the PR 9 engine.
//! 5. **Wire format** — `encode_batch → parse_batch_par → decode_batch`
//!    round-trips seeded random batches losslessly, and truncated or
//!    shape-malformed documents are rejected, never misread.

use relic_smt::config::StreamSettings;
use relic_smt::coordinator::stream::{
    decode_batch, encode_batch, encode_stream, generate_batches, run_pipeline,
};
use relic_smt::coordinator::{
    Deadline, EdgeDist, Engine, EngineConfig, GraphKernel, Request, Response, StreamConfig,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::graph::{oracle, pr, IncrementalAnalytics};
use relic_smt::json::{self, Value};
use relic_smt::probe::NoProbe;
use relic_smt::relic::{Par, PoolConfig, Relic, Schedule};
use relic_smt::testutil::check;

const SCALE: u32 = 7;
const SOURCE: u32 = 3;

fn bits(scores: &[f64]) -> Vec<u64> {
    scores.iter().map(|x| x.to_bits()).collect()
}

/// Small stream shape shared by the pipeline tests; the 2-deep queues
/// in `backpressure_never_drops_or_reorders` override `queue_capacity`.
fn small_cfg(seed: u64) -> StreamConfig {
    StreamConfig {
        enabled: true,
        scale: 6,
        batch: 32,
        batches: 10,
        queue_capacity: 4,
        recompute_interval: 3,
        source: 0,
        seed,
        pin: false,
    }
}

/// Full differential check of one incremental state against a
/// from-scratch recompute on the rebuilt graph.
fn assert_checkpoint(an: &IncrementalAnalytics, source: u32, tag: &str) {
    let rebuilt = an.graph().rebuild();
    assert_eq!(
        an.cc_labels(),
        oracle::components_min_label(&rebuilt),
        "{tag}: incremental CC diverged from the oracle"
    );
    assert_eq!(
        an.bfs_depths(),
        oracle::bfs_depths(&rebuilt, source),
        "{tag}: dynamic BFS diverged from the oracle"
    );
    let fresh = pr::pagerank(&rebuilt, pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe);
    assert_eq!(
        bits(an.pr_scores()),
        bits(&fresh),
        "{tag}: delta-PageRank is not bitwise equal to the serial kernel"
    );
}

#[test]
fn incremental_kernels_match_full_recomputes_at_every_checkpoint() {
    let relic = Relic::new();
    let par = Par::Relic(&relic);
    for dist in EdgeDist::all() {
        for seed in [11u64, 29] {
            let batches = generate_batches(dist, SCALE, 12, 40, seed);
            let mut an = IncrementalAnalytics::empty(1 << SCALE, SOURCE, 5);
            for (round, batch) in batches.iter().enumerate() {
                let outcome = an.apply_batch(batch, &par);
                assert!(
                    outcome.recompute_matched,
                    "{} seed {seed} round {round}: escape hatch mismatch",
                    dist.name()
                );
                let tag = format!("{} seed {seed} round {round}", dist.name());
                assert_checkpoint(&an, SOURCE, &tag);
            }
            assert_eq!(an.recomputes(), 2, "12 batches / interval 5");
            assert_eq!(an.recompute_mismatches(), 0);
        }
    }
}

#[test]
fn pipeline_final_state_matches_a_serial_replay() {
    // The threaded pipeline and a single-threaded replay of the same
    // generated stream are the same state machine: identical final
    // checksums and bitwise-identical scores, for both scenarios.
    for dist in EdgeDist::all() {
        let cfg = small_cfg(17);
        let (report, an) = run_pipeline(&cfg, encode_stream(dist, &cfg));
        let batches =
            generate_batches(dist, cfg.scale, cfg.batches, cfg.batch, cfg.seed);
        let mut replay =
            IncrementalAnalytics::empty(1 << cfg.scale, cfg.source, cfg.recompute_interval);
        for batch in &batches {
            replay.apply_batch(batch, &Par::Serial);
        }
        assert_eq!(report.checksums, replay.checksums(), "{}", dist.name());
        assert_eq!(bits(an.pr_scores()), bits(replay.pr_scores()), "{}", dist.name());
        assert_checkpoint(&an, cfg.source, dist.name());
    }
}

#[test]
fn same_seed_pipeline_runs_are_bitwise_identical() {
    let cfg = small_cfg(21);
    for dist in EdgeDist::all() {
        let run = || {
            let (report, an) = run_pipeline(&cfg, encode_stream(dist, &cfg));
            (report.emitted.clone(), report.checksums, bits(an.pr_scores()))
        };
        assert_eq!(run(), run(), "{}: seeded runs must be reproducible", dist.name());
    }
}

#[test]
fn backpressure_never_drops_or_reorders() {
    // 2-slot stage links against 24 large batches: the producer outruns
    // every stage, so the links saturate and the push side spins. The
    // contract is lossless FIFO delivery regardless.
    let cfg = StreamConfig {
        batch: 64,
        batches: 24,
        queue_capacity: 2,
        ..small_cfg(31)
    };
    let (report, _an) = run_pipeline(&cfg, encode_stream(EdgeDist::PowerLaw, &cfg));
    assert_eq!(report.batches_in, 24);
    assert_eq!(report.parse_errors, 0);
    assert_eq!(report.out_of_order, 0, "emit saw records out of input order");
    assert_eq!(report.emitted.len(), 24, "every document produces exactly one line");
    for (i, line) in report.emitted.iter().enumerate() {
        let doc = json::parse(line.as_bytes()).expect("emit lines are valid JSON");
        let seq = doc.get("seq").and_then(Value::as_u64).expect("emit line has seq");
        assert_eq!(seq, i as u64, "line {i} carries the wrong sequence number");
    }
}

#[test]
fn stream_off_engine_is_response_for_response_the_plain_engine() {
    // `[stream]` defaults off, and an off section materializes nothing:
    // engine construction never consults it. Operationally, running the
    // pipeline next to one engine must not perturb its request path,
    // and detaching the counters must restore its report byte for byte.
    let settings = StreamSettings::default();
    assert!(!settings.enabled, "[stream] must default off");
    let base = || EngineConfig {
        pool: PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
        ..EngineConfig::default()
    };
    let requests = |first: u64| -> Vec<Request> {
        let kernels = GraphKernel::all();
        (0..2 * kernels.len())
            .map(|i| Request {
                id: first + i as u64,
                kernel: kernels[i % kernels.len()],
                graph: paper_graph(),
                source: 0,
                deadline: Deadline::none(),
            })
            .collect()
    };
    let sig = |responses: &[Response]| -> Vec<(u64, relic_smt::coordinator::RequestResult)> {
        responses.iter().map(|r| (r.id, r.result.clone())).collect()
    };
    let mut plain = Engine::new(base());
    let mut beside_stream = Engine::new(base());
    for round in 0..3u64 {
        let a = plain.process_batch(requests(round * 100));
        let b = beside_stream.process_batch(requests(round * 100));
        assert_eq!(sig(&a), sig(&b), "round {round}: responses diverged");
        if round == 1 {
            // Run a whole pipeline between serving rounds on one engine
            // only; its subsequent responses must not change.
            let scfg = small_cfg(5);
            let (report, _an) =
                run_pipeline(&scfg, encode_stream(EdgeDist::Uniform, &scfg));
            let before = beside_stream.report();
            beside_stream.set_stream(Some(report.snapshot()));
            assert!(beside_stream.report().contains("stream: "), "counters attached");
            beside_stream.set_stream(None);
            assert_eq!(
                beside_stream.report(),
                before,
                "detaching the stream counters must restore the report byte-identically"
            );
        }
    }
}

#[test]
fn wire_roundtrip_preserves_seeded_random_batches() {
    let relic = Relic::new();
    let par = Par::Relic(&relic);
    check(40, |rng| {
        let seq = rng.below(1 << 48);
        let count = rng.range(0, 65);
        let edges: Vec<(u32, u32)> = (0..count)
            .map(|_| (rng.below(1 << 32) as u32, rng.below(1 << 32) as u32))
            .collect();
        let bytes = encode_batch(seq, &edges);
        let docs = [bytes.as_slice()];
        let parsed = json::parse_batch_par(&docs, &par);
        let value = parsed[0].as_ref().map_err(|e| format!("parse failed: {e}"))?;
        let (got_seq, got_edges) = decode_batch(value).map_err(str::to_string)?;
        if got_seq != seq || got_edges != edges {
            return Err(format!("round-trip mutated the batch (seq {seq})"));
        }
        Ok(())
    });
}

#[test]
fn parse_batch_par_round_trips_whole_streams_under_every_schedule() {
    let cfg = small_cfg(13);
    let expected =
        generate_batches(EdgeDist::Uniform, cfg.scale, cfg.batches, cfg.batch, cfg.seed);
    let docs = encode_stream(EdgeDist::Uniform, &cfg);
    let refs: Vec<&[u8]> = docs.iter().map(|d| d.as_slice()).collect();
    let relic = Relic::new();
    for sched in Schedule::all() {
        let par = Par::Relic(&relic).with_schedule(sched);
        let parsed = json::parse_batch_par(&refs, &par);
        assert_eq!(parsed.len(), docs.len());
        for (i, result) in parsed.iter().enumerate() {
            let value = result.as_ref().expect("stream documents parse");
            let (seq, edges) = decode_batch(value).expect("stream documents decode");
            assert_eq!(seq, i as u64, "{}", sched.name());
            assert_eq!(edges, expected[i], "{} batch {i}", sched.name());
        }
    }
}

#[test]
fn truncated_and_malformed_documents_are_rejected() {
    // Every strict prefix of a valid wire document must fail to parse —
    // a truncated write can never be misread as a shorter valid batch.
    check(20, |rng| {
        let edges: Vec<(u32, u32)> = (0..rng.range(1, 9))
            .map(|_| (rng.below(64) as u32, rng.below(64) as u32))
            .collect();
        let bytes = encode_batch(rng.below(1000), &edges);
        for cut in 0..bytes.len() {
            if json::parse(&bytes[..cut]).is_ok() {
                return Err(format!("truncation at {cut}/{} parsed", bytes.len()));
            }
        }
        Ok(())
    });
    // Shape-malformed documents parse as JSON but fail strict decode.
    for bad in [
        r#"{"edges": [[1, 2]]}"#,
        r#"{"seq": 1.5, "edges": []}"#,
        r#"{"seq": 1}"#,
        r#"{"seq": 1, "edges": 2}"#,
        r#"{"seq": 1, "edges": [[1, 2, 3]]}"#,
        r#"{"seq": 1, "edges": [[1, 2.5]]}"#,
        r#"{"seq": 1, "edges": [[1, -2]]}"#,
        r#"{"seq": 1, "edges": [[1, 4294967296]]}"#,
    ] {
        let doc = json::parse(bad.as_bytes()).expect("shape-malformed is still JSON");
        assert!(decode_batch(&doc).is_err(), "decode accepted {bad}");
    }
}
