//! Integration: the three-layer contract. AOT artifacts (JAX/Pallas,
//! lowered by `make artifacts`) must load through the PJRT runtime and
//! agree with the native Rust kernels on the paper's input graph.
//!
//! Skipped (with a note) when `artifacts/manifest.json` is absent.

use std::path::{Path, PathBuf};

use relic_smt::graph::{dense, kronecker::paper_graph};
use relic_smt::probe::NoProbe;
use relic_smt::runtime::GraphExecutor;

fn artifacts_dir() -> Option<PathBuf> {
    // Tests run from the crate root.
    for candidate in ["artifacts", "../artifacts"] {
        let p = Path::new(candidate);
        if p.join("manifest.json").exists() {
            return Some(p.to_path_buf());
        }
    }
    None
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn pagerank_roundtrip_matches_native() {
    let dir = require_artifacts!();
    let mut exec = GraphExecutor::new(&dir).unwrap();
    let g = paper_graph();
    let n = g.num_vertices();
    let pjrt = exec
        .execute("pagerank", n, &[dense::transition(&g), dense::uniform(n)])
        .unwrap();
    let native = relic_smt::graph::pr::pagerank(&g, 20, 0.0, &mut NoProbe);
    for (v, (p, q)) in pjrt.iter().zip(&native).enumerate() {
        assert!((*p as f64 - q).abs() < 1e-5, "vertex {v}: {p} vs {q}");
    }
    // Distribution property survives the stack (dangling/isolated
    // vertices drop mass, so compare against the native sum, not 1.0).
    let sum: f32 = pjrt.iter().sum();
    let native_sum: f64 = native.iter().sum();
    assert!((sum as f64 - native_sum).abs() < 1e-4, "sum {sum} vs {native_sum}");
}

#[test]
fn bfs_and_sssp_roundtrip_match_native() {
    let dir = require_artifacts!();
    let mut exec = GraphExecutor::new(&dir).unwrap();
    let g = paper_graph();
    let n = g.num_vertices();
    for source in [0u32, 7, 31] {
        let pjrt = exec
            .execute("bfs", n, &[dense::adjacency(&g), dense::one_hot(n, source)])
            .unwrap();
        let native = relic_smt::graph::bfs::bfs(&g, source, &mut NoProbe);
        for (v, (p, q)) in pjrt.iter().zip(&native).enumerate() {
            let p = if p.is_infinite() { u32::MAX } else { *p as u32 };
            assert_eq!(p, *q, "bfs src {source} vertex {v}");
        }
        let pjrt = exec
            .execute("sssp", n, &[dense::weights_inf(&g), dense::one_hot(n, source)])
            .unwrap();
        let native = relic_smt::graph::sssp::delta_stepping(
            &g,
            source,
            relic_smt::graph::sssp::DEFAULT_DELTA,
            &mut NoProbe,
        );
        for (v, (p, q)) in pjrt.iter().zip(&native).enumerate() {
            let p = if p.is_infinite() { u32::MAX } else { *p as u32 };
            assert_eq!(p, *q, "sssp src {source} vertex {v}");
        }
    }
}

#[test]
fn cc_tc_bc_roundtrip_match_native() {
    let dir = require_artifacts!();
    let mut exec = GraphExecutor::new(&dir).unwrap();
    let g = paper_graph();
    let n = g.num_vertices();

    let cc = exec.execute("cc", n, &[dense::w0(&g)]).unwrap();
    let native_cc = relic_smt::graph::cc::shiloach_vishkin(&g, &mut NoProbe);
    assert_eq!(
        cc.iter().map(|v| *v as u32).collect::<Vec<_>>(),
        native_cc
    );

    let tc = exec.execute("tc", n, &[dense::adjacency(&g)]).unwrap();
    let native_tc = relic_smt::graph::tc::triangle_count(&g, &mut NoProbe);
    assert_eq!(tc[0] as u64, native_tc);

    let bc = exec.execute("bc", n, &[dense::adjacency(&g)]).unwrap();
    let native_bc = relic_smt::graph::bc::brandes(&g, &mut NoProbe);
    for (v, (p, q)) in bc.iter().zip(&native_bc).enumerate() {
        assert!(
            (*p as f64 - q).abs() < 1e-2,
            "bc vertex {v}: {p} vs {q}"
        );
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let dir = require_artifacts!();
    let mut exec = GraphExecutor::new(&dir).unwrap();
    let g = paper_graph();
    let n = g.num_vertices();
    let inputs = [dense::adjacency(&g)];
    let t_first = std::time::Instant::now();
    exec.execute("tc", n, &inputs).unwrap();
    let first = t_first.elapsed();
    let t_rest = std::time::Instant::now();
    for _ in 0..10 {
        exec.execute("tc", n, &inputs).unwrap();
    }
    let per_exec = t_rest.elapsed() / 10;
    assert!(
        per_exec < first,
        "cached executions ({per_exec:?}) should beat compile+run ({first:?})"
    );
    assert_eq!(exec.executions, 11);
}
