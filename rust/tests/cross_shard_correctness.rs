//! Cross-shard cooperative-parallelism correctness suite.
//!
//! The tentpole invariant: a whale request that borrows idle
//! pair-shards produces results **bitwise identical** to the serial and
//! single-pair paths — chunk ownership is a pure function of `(range,
//! boundaries, shard set)`, never of timing. On top of that:
//! `max_borrow = 0` is response-for-response the pre-borrowing engine,
//! revocation at chunk granularity loses and duplicates nothing, and
//! borrowing composes with the fault-injection machinery (a killed
//! shard mid-stream does not corrupt a later whale).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;

use relic_smt::coordinator::{
    run_native_kernel, run_native_kernel_par, Deadline, Engine, EngineConfig, GraphKernel,
    Request, RequestResult,
};
use relic_smt::graph::kronecker::{kronecker_graph, KroneckerParams, PAPER_SEED};
use relic_smt::graph::CsrGraph;
use relic_smt::relic::{
    with_lease, CrossCtx, FaultPlan, LeaseBroker, Par, PoolConfig, Relic, Schedule,
};

/// A graph big enough that the kernels' hot loops actually split into
/// multiple cross-shard chunks (the paper graph's 32 vertices fit in
/// one grain and would exercise nothing).
fn whale_graph() -> CsrGraph {
    kronecker_graph(&KroneckerParams::gap(8, 16, PAPER_SEED))
}

/// A broker with both shards' eligibility hooks bound (depth 0, not
/// quarantined) plus a borrower thread that keeps serving shard 1's
/// leases until told to stop. Returns `(broker, stop flag, handle)`.
fn broker_with_borrower() -> (Arc<LeaseBroker>, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let broker = Arc::new(LeaseBroker::new(2));
    for s in 0..2 {
        broker.bind(s, Arc::new(AtomicUsize::new(0)), Arc::new(AtomicBool::new(false)));
    }
    let stop = Arc::new(AtomicBool::new(false));
    let handle = {
        let broker = Arc::clone(&broker);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let relic = Relic::new();
            let should_return = {
                let stop = Arc::clone(&stop);
                move || stop.load(Ordering::Acquire)
            };
            while !stop.load(Ordering::Acquire) {
                if !broker.serve(1, &relic, &should_return) {
                    std::thread::yield_now();
                }
            }
        })
    };
    (broker, stop, handle)
}

#[test]
fn borrowed_kernels_match_serial_and_pair_under_every_schedule() {
    let g = whale_graph();
    let (broker, stop, handle) = broker_with_borrower();
    let ctx = CrossCtx { broker, shard: 0, max_borrow: 1, offer_depth: 0 };
    let relic = Relic::new();
    for schedule in [Schedule::Static, Schedule::Dynamic, Schedule::EdgeBalanced] {
        for kernel in GraphKernel::all() {
            let serial = run_native_kernel(kernel, &g, 0);
            let pair =
                run_native_kernel_par(kernel, &g, 0, &Par::Scheduled(&relic, schedule));
            let crossed =
                with_lease(&ctx, &relic, schedule, |par| run_native_kernel_par(kernel, &g, 0, par));
            assert_eq!(pair, serial, "{kernel:?}/{schedule:?}: pair vs serial");
            assert_eq!(crossed, serial, "{kernel:?}/{schedule:?}: borrowed vs serial");
        }
    }
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
}

#[test]
fn revocation_mid_loop_loses_and_duplicates_nothing() {
    const N: usize = 1 << 12;
    let broker = Arc::new(LeaseBroker::new(2));
    for s in 0..2 {
        broker.bind(s, Arc::new(AtomicUsize::new(0)), Arc::new(AtomicBool::new(false)));
    }
    let stop = Arc::new(AtomicBool::new(false));
    let revoke = Arc::new(AtomicBool::new(false));
    // The borrower's should-return predicate watches `revoke`, which the
    // owner flips from inside the loop body: the borrower hands its
    // lease back at the next chunk boundary while the owner keeps
    // claiming — exactly-once must hold across the handover.
    let handle = {
        let broker = Arc::clone(&broker);
        let stop = Arc::clone(&stop);
        let revoke = Arc::clone(&revoke);
        std::thread::spawn(move || {
            let relic = Relic::new();
            let should_return = {
                let stop = Arc::clone(&stop);
                let revoke = Arc::clone(&revoke);
                move || stop.load(Ordering::Acquire) || revoke.load(Ordering::Acquire)
            };
            while !stop.load(Ordering::Acquire) {
                if !broker.serve(1, &relic, &should_return) {
                    std::thread::yield_now();
                }
            }
        })
    };
    let ctx = CrossCtx { broker: Arc::clone(&broker), shard: 0, max_borrow: 1, offer_depth: 0 };
    let relic = Relic::new();
    let hits: Vec<AtomicU32> = (0..N).map(|_| AtomicU32::new(0)).collect();
    for round in 0..8 {
        revoke.store(false, Ordering::Release);
        for h in &hits {
            h.store(0, Ordering::Relaxed);
        }
        let trigger = N / 4 + round * 16;
        with_lease(&ctx, &relic, Schedule::Dynamic, |par| {
            par.for_each_index(0..N, 16, |i| {
                if i == trigger {
                    revoke.store(true, Ordering::Release);
                }
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "round {round}: index {i} hit count");
        }
    }
    stop.store(true, Ordering::Release);
    handle.join().unwrap();
}

fn mixed_requests(n: usize, graph: &CsrGraph) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..n)
        .map(|i| Request {
            id: i as u64,
            kernel: kernels[i % kernels.len()],
            graph: graph.clone(),
            source: (i % 16) as u32,
            deadline: Deadline::none(),
        })
        .collect()
}

fn engine_with_borrow(max_borrow: usize) -> Engine {
    Engine::new(EngineConfig {
        pool: PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
        max_borrow,
        ..EngineConfig::default()
    })
}

#[test]
fn max_borrow_zero_is_response_for_response_the_default_engine() {
    // The degeneracy gate: `max_borrow = 0` must not merely compute the
    // same checksums — the whole response stream (ids, order, results)
    // must be identical to the default engine's, which never built a
    // broker at all.
    let g = whale_graph();
    let n = 24;
    let mut zero = engine_with_borrow(0);
    let mut default = Engine::new(EngineConfig {
        pool: PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
        ..EngineConfig::default()
    });
    assert!(zero.lease_stats().is_none(), "max_borrow = 0 builds no broker");
    assert!(default.lease_stats().is_none());
    let a = zero.process_batch(mixed_requests(n, &g));
    let b = default.process_batch(mixed_requests(n, &g));
    assert_eq!(a.len(), n);
    let sig = |responses: &[relic_smt::coordinator::Response]| -> Vec<(u64, RequestResult)> {
        responses.iter().map(|r| (r.id, r.result.clone())).collect()
    };
    assert_eq!(sig(&a), sig(&b), "response-for-response identical");
}

#[test]
fn borrowing_engine_matches_non_borrowing_results() {
    let g = whale_graph();
    let n = 24;
    let mut plain = engine_with_borrow(0);
    let mut borrowing = engine_with_borrow(1);
    assert_eq!(
        borrowing.lease_stats().map(|s| s.served + s.revoked + s.chunks_lent),
        Some(0),
        "broker exists but has seen no traffic yet"
    );
    let a = plain.process_batch(mixed_requests(n, &g));
    let b = borrowing.process_batch(mixed_requests(n, &g));
    let sig = |responses: &[relic_smt::coordinator::Response]| -> Vec<(u64, RequestResult)> {
        responses.iter().map(|r| (r.id, r.result.clone())).collect()
    };
    assert_eq!(sig(&a), sig(&b), "borrowing must never change results");
}

#[test]
fn borrowing_composes_with_fault_injection() {
    // Kill shard 1 on its first batch while borrowing is armed: the
    // supervisor quarantines and recovers it, every accepted request is
    // answered (correct checksum or a typed failure — never silence),
    // and a subsequent whale request still computes the exact serial
    // checksum through whatever shard set is healthy by then.
    let g = whale_graph();
    let n = 16;
    let mut e = Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(2),
            pin: false,
            fault: Some(Arc::new(FaultPlan::new().with_kill(1, 1))),
            ..PoolConfig::default()
        },
        max_borrow: 1,
        ..EngineConfig::default()
    });
    let requests = mixed_requests(n, &g);
    let expected: Vec<u64> =
        requests.iter().map(|r| run_native_kernel(r.kernel, &r.graph, r.source)).collect();
    let responses = e.process_batch(requests);
    assert_eq!(responses.len(), n, "no-drop invariant under a killed shard");
    for (i, r) in responses.iter().enumerate() {
        match &r.result {
            RequestResult::Native(sum) => assert_eq!(*sum, expected[i], "request {i}"),
            RequestResult::Failed(_) => {} // typed loss is legal mid-kill
            other => panic!("request {i}: unexpected result {other:?}"),
        }
    }
    // Post-recovery whale: exact checksum, engine fully usable.
    let whale = Request {
        id: 999,
        kernel: GraphKernel::Pr,
        graph: g.clone(),
        source: 0,
        deadline: Deadline::none(),
    };
    let serial = run_native_kernel(GraphKernel::Pr, &g, 0);
    let out = e.process_batch(vec![whale]);
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].result, RequestResult::Native(serial));
}
