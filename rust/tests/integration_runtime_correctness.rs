//! Integration: every task runtime (Relic + the seven baseline models)
//! must compute *identical results* to serial execution when driving
//! real kernel pairs — scheduling must never change outputs. Also
//! exercises failure-ish edges: zero-size graphs, repeated reuse,
//! interleaved kernels.

use std::sync::atomic::{AtomicU64, Ordering};

use relic_smt::bench::Workload;
use relic_smt::graph::{kronecker_graph, CsrGraph, KroneckerParams};
use relic_smt::probe::NoProbe;
use relic_smt::relic::Relic;
use relic_smt::runtimes;

#[test]
fn all_runtimes_produce_serial_results_on_all_kernels() {
    let workloads = Workload::all();
    let expected: Vec<u64> = workloads.iter().map(|w| 2 * w.run_native()).collect();
    for name in runtimes::FRAMEWORK_NAMES {
        let mut rt = runtimes::by_name(name, None).unwrap();
        for (w, want) in workloads.iter().zip(&expected) {
            let sum = AtomicU64::new(0);
            for _ in 0..20 {
                sum.store(0, Ordering::SeqCst);
                rt.run_pair(
                    &|| {
                        sum.fetch_add(w.run_native(), Ordering::SeqCst);
                    },
                    &|| {
                        sum.fetch_add(w.run_native(), Ordering::SeqCst);
                    },
                );
                assert_eq!(sum.load(Ordering::SeqCst), *want, "{name}/{}", w.name);
            }
        }
    }
}

#[test]
fn relic_produces_serial_results_on_all_kernels() {
    let relic = Relic::new();
    for w in Workload::all() {
        let want = 2 * w.run_native();
        let sum = AtomicU64::new(0);
        let task = || {
            sum.fetch_add(w.run_native(), Ordering::SeqCst);
        };
        relic.pair(&task, &task);
        assert_eq!(sum.load(Ordering::SeqCst), want, "relic/{}", w.name);
    }
}

#[test]
fn kernels_handle_degenerate_graphs() {
    use relic_smt::graph::{bc, bfs, cc, pr, sssp, tc};
    // Single vertex, no edges.
    let g = CsrGraph::from_undirected_weighted(1, &[], true);
    assert_eq!(bfs::bfs(&g, 0, &mut NoProbe), vec![0]);
    assert_eq!(cc::shiloach_vishkin(&g, &mut NoProbe), vec![0]);
    assert_eq!(sssp::delta_stepping(&g, 0, 64, &mut NoProbe), vec![0]);
    assert_eq!(tc::triangle_count(&g, &mut NoProbe), 0);
    assert_eq!(bc::brandes(&g, &mut NoProbe), vec![0.0]);
    // Dangling mass is dropped (GAP semantics): an isolated vertex
    // keeps only the teleport share (1 - d) / n = 0.15.
    let scores = pr::pagerank(&g, 20, 1e-4, &mut NoProbe);
    assert!((scores[0] - 0.15).abs() < 1e-9, "{}", scores[0]);
    // Empty graph (0 vertices).
    let g0 = CsrGraph::from_undirected_weighted(0, &[], true);
    assert!(pr::pagerank(&g0, 20, 1e-4, &mut NoProbe).is_empty());
    assert!(cc::shiloach_vishkin(&g0, &mut NoProbe).is_empty());
    assert_eq!(tc::triangle_count(&g0, &mut NoProbe), 0);
}

#[test]
fn runtimes_survive_interleaved_kernel_mix() {
    // A runtime must not corrupt state when consecutive pairs run
    // different kernels (descriptor reuse, epoch bookkeeping).
    let g = kronecker_graph(&KroneckerParams::gap(6, 8, 3));
    let mut rt = runtimes::by_name("opencilk", None).unwrap();
    let total = AtomicU64::new(0);
    for i in 0..50u32 {
        let a = i % 3;
        let task_a = || {
            let v = match a {
                0 => relic_smt::graph::bfs::checksum(&relic_smt::graph::bfs::bfs(
                    &g, 0, &mut NoProbe,
                )),
                1 => relic_smt::graph::tc::triangle_count(&g, &mut NoProbe),
                _ => relic_smt::graph::cc::checksum(&relic_smt::graph::cc::shiloach_vishkin(
                    &g,
                    &mut NoProbe,
                )),
            };
            total.fetch_add(v, Ordering::Relaxed);
        };
        rt.run_pair(&task_a, &task_a);
    }
    assert!(total.load(Ordering::Relaxed) > 0);
}
