//! Scheduling regression tests (ISSUE 3): on a *power-law* Kronecker
//! fixture — the degree distribution where static splitting actually
//! imbalances — every kernel's checksum under `Schedule::Dynamic` and
//! `Schedule::EdgeBalanced` must be bitwise-equal to `Par::Serial`,
//! the dynamic float reduce must be a single bit pattern across 100
//! runs, and the scope must never be entered for sub-grain ranges.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};

use relic_smt::coordinator::{run_native_kernel, run_native_kernel_par, GraphKernel};
use relic_smt::graph::kronecker::{kronecker_graph, KroneckerParams};
use relic_smt::graph::CsrGraph;
use relic_smt::relic::{Grain, Par, Relic, RelicConfig, Schedule};

/// The skewed fixture: R-MAT is power-law-ish by construction, and at
/// scale 9 the graph is big enough that every kernel loop splits into
/// many chunks while the whole suite still runs in test time.
fn skewed_graph() -> CsrGraph {
    kronecker_graph(&KroneckerParams::gap(9, 8, 7))
}

#[test]
fn fixture_is_power_law_skewed() {
    let g = skewed_graph();
    let n = g.num_vertices();
    let avg = g.num_directed_edges() as f64 / n as f64;
    let max = (0..n as u32).map(|v| g.degree(v)).max().unwrap() as f64;
    assert!(
        max > 4.0 * avg,
        "fixture lost its skew (max degree {max}, avg {avg}) — these tests \
         would no longer exercise imbalanced chunks"
    );
}

#[test]
fn dynamic_and_edge_balanced_checksums_equal_serial_on_skewed_graph() {
    let g = skewed_graph();
    let relic = Relic::new();
    for kernel in GraphKernel::all() {
        let want = run_native_kernel(kernel, &g, 3);
        assert_eq!(
            run_native_kernel_par(kernel, &g, 3, &Par::Serial),
            want,
            "{kernel:?} Par::Serial"
        );
        for schedule in [Schedule::Dynamic, Schedule::EdgeBalanced] {
            let par = Par::Relic(&relic).with_schedule(schedule);
            for round in 0..3 {
                assert_eq!(
                    run_native_kernel_par(kernel, &g, 3, &par),
                    want,
                    "{kernel:?} under {} (round {round})",
                    schedule.name()
                );
            }
        }
    }
}

#[test]
fn dynamic_checksums_survive_queue_overflow_on_skewed_graph() {
    // A 2-slot queue forces wave submissions to overflow constantly;
    // the inline fallback must preserve every checksum.
    let g = skewed_graph();
    let relic = Relic::with_config(RelicConfig {
        queue_capacity: 2,
        ..RelicConfig::default()
    });
    for kernel in GraphKernel::all() {
        let want = run_native_kernel(kernel, &g, 0);
        for schedule in [Schedule::Dynamic, Schedule::EdgeBalanced] {
            let par = Par::Relic(&relic).with_schedule(schedule);
            assert_eq!(
                run_native_kernel_par(kernel, &g, 0, &par),
                want,
                "{kernel:?} under {} with queue pressure",
                schedule.name()
            );
        }
    }
    let stats = relic.stats();
    assert_eq!(stats.submitted, stats.completed, "all wave tasks drained");
}

#[test]
fn dynamic_float_reduce_yields_a_single_bit_pattern_across_100_runs() {
    let relic = Relic::new();
    let par = Par::Relic(&relic).with_schedule(Schedule::Dynamic);
    let mut seen = HashSet::new();
    for _ in 0..100 {
        let v = par.reduce(0..5000, 7, 0.0f64, |i| (i as f64).sqrt(), |a, b| a + b);
        seen.insert(v.to_bits());
    }
    assert_eq!(
        seen.len(),
        1,
        "dynamic reduce must not depend on which thread claims which chunk"
    );
}

#[test]
fn edge_balanced_float_reduce_yields_a_single_bit_pattern_across_100_runs() {
    let relic = Relic::new();
    let par = Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced);
    let n = 5000usize;
    // A skewed (quadratic) boundary stands in for the CSR bisection.
    let bound = |i: usize, k: usize| n * i * i / (k * k);
    let mut seen = HashSet::new();
    for _ in 0..100 {
        let v = par.reduce(
            0..n,
            Grain::Bounded(7, &bound),
            0.0f64,
            |i| (i as f64).sqrt(),
            |a, b| a + b,
        );
        seen.insert(v.to_bits());
    }
    assert_eq!(seen.len(), 1, "edge-balanced reduce must be run-to-run deterministic");
}

#[test]
fn tiny_ranges_never_enter_a_scope() {
    let relic = Relic::new();
    for schedule in Schedule::all() {
        let par = Par::Relic(&relic).with_schedule(schedule);
        let before = relic.stats().submitted;
        let sum = AtomicU64::new(0);
        par.for_each_index(0..4, 16, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        let mut out = [0u64; 4];
        par.map_into(&mut out, 16, |i| i as u64 * 2);
        let red = par.reduce(0..4, 16, 0u64, |i| i as u64, |a, b| a + b);
        let chunks = par.chunk_map(0..4, 16, |sub| sub.len());
        assert_eq!(sum.load(Ordering::Relaxed), 6);
        assert_eq!(out, [0, 2, 4, 6]);
        assert_eq!(red, 6);
        assert_eq!(chunks, vec![4]);
        assert_eq!(
            relic.stats().submitted,
            before,
            "{}: a 4-element loop must not pay the submit/wait handshake",
            schedule.name()
        );
    }
}

#[test]
fn scheduling_counters_are_exposed_and_consistent() {
    let relic = Relic::new();
    let par = Par::Relic(&relic).with_schedule(Schedule::Dynamic);
    let sum = AtomicU64::new(0);
    par.for_each_index(0..100_000, 64, |i| {
        sum.fetch_add(i as u64 & 1, Ordering::Relaxed);
    });
    let stats = relic.stats();
    assert_eq!(sum.load(Ordering::Relaxed), 50_000);
    assert_eq!(stats.submitted, stats.completed);
    // Whatever the interleaving, the counters never exceed the chunk
    // volume of the loop (MAX_DYN_CHUNKS chunks for one dynamic split).
    let max_chunks = relic_smt::relic::MAX_DYN_CHUNKS as u64;
    assert!(stats.helped_chunks <= max_chunks, "helped {}", stats.helped_chunks);
    assert!(stats.inline_fallback <= max_chunks, "inline {}", stats.inline_fallback);
}
