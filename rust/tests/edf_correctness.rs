//! Integration: the self-measuring engine (per-shard service-time EMA
//! routing + EDF batch ordering) preserves every PR 4 guarantee.
//!
//! The acceptance contract pinned here:
//! * with `edf = false` and `ema_alpha = 0` the engine is bit-for-bit
//!   PR 4 (same responses, same counters, zero estimator/EDF activity);
//! * the EMA converges to a known synthetic service time within a
//!   bounded number of samples, and the engine-level estimator fills
//!   from real completions;
//! * EDF never reorders deadline-less requests relative to each other;
//! * counter reconciliation still holds under EDF + shedding:
//!   submitted = completed + shed.

use std::time::{Duration, Instant};

use relic_smt::coordinator::{
    edf_order, run_native_kernel, AdmissionConfig, Coordinator, Deadline, Engine, EngineConfig,
    GraphKernel, Request, Router, RouterConfig, ShedPolicy,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::metrics::ServiceEstimator;
use relic_smt::relic::PoolConfig;

/// Unpinned engine: CI containers may refuse affinity syscalls.
fn engine(
    shards: usize,
    channel_capacity: usize,
    max_batch: usize,
    admission: AdmissionConfig,
) -> Engine {
    Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(shards),
            pin: false,
            channel_capacity,
            max_batch,
            ..PoolConfig::default()
        },
        admission,
        ..EngineConfig::default()
    })
}

fn req(id: u64, kernel: GraphKernel, source: u32) -> Request {
    Request {
        id,
        kernel,
        graph: paper_graph(),
        source,
        deadline: Deadline::none(),
    }
}

/// Mixed batch cycling every kernel over several sources.
fn mixed_batch(n: usize) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..n)
        .map(|i| req(i as u64, kernels[i % kernels.len()], (i % 8) as u32))
        .collect()
}

#[test]
fn ema_converges_to_synthetic_service_time_within_bounded_samples() {
    // Synthetic stream: a constant 25 µs service time. With alpha 0.25
    // the EMA's error shrinks by 3/4 per sample, so 40 samples bring a
    // 100× initial error under 0.1%.
    let est = ServiceEstimator::default();
    est.configure(0.25, 0);
    est.record(0, 250); // deliberately far-off first sample (snaps)
    for _ in 0..40 {
        est.record(0, 25_000);
    }
    let got = est.estimate_ns(0);
    assert!(
        (24_900..=25_100).contains(&got),
        "EMA must converge to the synthetic 25 µs service time, got {got} ns"
    );
    // A shifted workload re-converges: the estimator tracks drift.
    for _ in 0..40 {
        est.record(0, 100_000);
    }
    let got = est.estimate_ns(0);
    assert!((99_000..=101_000).contains(&got), "EMA tracks drift, got {got} ns");
}

#[test]
fn engine_level_ema_fills_from_real_completions() {
    let mut e = engine(
        2,
        64,
        8,
        AdmissionConfig { ema_alpha: 0.5, ..Default::default() },
    );
    let n = 24;
    for r in mixed_batch(n) {
        assert!(e.submit(r).is_accepted());
    }
    assert_eq!(e.drain().len(), n);
    let agg = e.aggregated_metrics();
    let est = &agg.service_estimator;
    let mut samples = 0;
    for k in GraphKernel::all() {
        samples += est.samples(k.class());
        assert!(
            est.estimate_ns(k.class()) > 0,
            "{k:?}: every exercised class has a measured estimate"
        );
    }
    assert_eq!(samples, n as u64, "exactly one EMA sample per completion");
    assert!(est.mean_estimate_ns() > 0);
}

#[test]
fn edf_never_reorders_deadline_less_requests_among_themselves() {
    // Ordering-rule level: under arbitrary deadline mixes, the
    // deadline-less subsequence of the EDF order is exactly its FIFO
    // subsequence (exhaustive over every deadline/none pattern of a
    // 6-request batch).
    let now = Instant::now();
    for mask in 0u32..(1 << 6) {
        let deadlines: Vec<Deadline> = (0..6)
            .map(|i| {
                if mask & (1 << i) != 0 {
                    // Descending deadlines so EDF genuinely reorders.
                    Deadline::at(now + Duration::from_millis(100 - 10 * i as u64))
                } else {
                    Deadline::none()
                }
            })
            .collect();
        let order = edf_order(deadlines.clone());
        let none_positions: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| deadlines[i].is_none())
            .collect();
        assert!(
            none_positions.windows(2).all(|w| w[0] < w[1]),
            "mask {mask:#b}: deadline-less requests reordered: {none_positions:?}"
        );
    }

    // Engine level: an all-deadline-less run under EDF produces the
    // identical responses and pairing metrics as FIFO — EDF on
    // deadline-less traffic is the identity.
    let mut fifo = engine(1, 64, 8, AdmissionConfig::default());
    let mut edf = engine(
        1,
        64,
        8,
        AdmissionConfig { edf: true, ..Default::default() },
    );
    let want = fifo.process_batch(mixed_batch(18));
    let got = edf.process_batch(mixed_batch(18));
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.backend, w.backend);
        assert_eq!(g.result, w.result);
    }
    let agg = edf.aggregated_metrics();
    assert_eq!(agg.admission.edf_reorders.get(), 0, "no deadlines → no reorders");
    assert_eq!(agg.admission.deadline_misses_avoided.get(), 0);
}

#[test]
fn edf_off_and_alpha_zero_is_bit_for_bit_pr4() {
    // The acceptance pin: explicit {edf: false, ema_alpha: 0} equals
    // both the default-config engine and the single-pair coordinator —
    // same responses (ids, backends, checksums), same counters, and
    // zero estimator/EDF state. Capacity 1 keeps the PR 2/PR 4
    // backpressure regime in the loop.
    let n = 24;
    let mut single = Coordinator::with_parts(Router::new(RouterConfig::default(), None), None);
    let want = single.process_batch(mixed_batch(n));

    let explicit = AdmissionConfig {
        shed: ShedPolicy::Never,
        service_estimate_ns: 0,
        ema_alpha: 0.0,
        edf: false,
    };
    assert_eq!(explicit, AdmissionConfig::default(), "the PR 4 shape IS the default");

    let mut e = engine(1, 1, 1, explicit);
    let got = e.process_batch(mixed_batch(n));
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.backend, w.backend);
        assert_eq!(g.result, w.result);
    }
    let agg = e.aggregated_metrics();
    assert_eq!(agg.native_requests.get(), n as u64);
    assert_eq!(agg.admission.shed_requests.get(), 0);
    assert_eq!(agg.admission.edf_reorders.get(), 0);
    assert_eq!(agg.admission.deadline_misses_avoided.get(), 0);
    assert!(!agg.service_estimator.is_measuring(), "alpha 0 never measures");
    for k in GraphKernel::all() {
        assert_eq!(agg.service_estimator.samples(k.class()), 0);
        assert_eq!(agg.service_estimator.estimate_ns(k.class()), 0, "{k:?}");
    }
}

#[test]
fn static_estimate_still_floors_the_measured_engine() {
    // ema_alpha > 0 with a static floor: before any Bc completion the
    // Bc estimate reads the floor; the floor also never lets measured
    // estimates sink below it (shedding stays conservative).
    let mut e = engine(
        1,
        64,
        8,
        AdmissionConfig {
            service_estimate_ns: 50_000,
            ema_alpha: 0.5,
            ..Default::default()
        },
    );
    let agg = e.aggregated_metrics();
    assert_eq!(
        agg.service_estimator.estimate_ns(GraphKernel::Bc.class()),
        50_000,
        "unmeasured class reads the seed/floor"
    );
    for i in 0..6 {
        assert!(e.submit(req(i, GraphKernel::Tc, 0)).is_accepted());
    }
    assert_eq!(e.drain().len(), 6);
    let agg = e.aggregated_metrics();
    assert!(
        agg.service_estimator.estimate_ns(GraphKernel::Tc.class()) >= 50_000,
        "estimates never sink below the configured floor"
    );
}

#[test]
fn edf_with_shedding_reconciles_submitted_completed_shed() {
    // EDF + PastDeadline shedding + deadline skew: everything still
    // reconciles — submitted = completed + shed — and nothing accepted
    // is lost or reordered in the response stream.
    let mut e = engine(
        2,
        64,
        8,
        AdmissionConfig {
            shed: ShedPolicy::PastDeadline,
            edf: true,
            ema_alpha: 0.25,
            ..Default::default()
        },
    );
    let n = 30usize;
    let g = paper_graph();
    let kernels = GraphKernel::all();
    let mut submitted = 0u64;
    for i in 0..n {
        let mut r = req(i as u64, kernels[i % kernels.len()], (i % 8) as u32);
        r.deadline = if i % 5 == 4 {
            // Every fifth request arrives already expired → shed.
            Deadline::at(Instant::now() - Duration::from_millis(1))
        } else {
            // Generous, non-monotone deadlines exercise EDF ordering.
            Deadline::within(Duration::from_secs(3600 + ((7 * i) % 11) as u64 * 60))
        };
        let _ = e.submit(r);
        submitted += 1;
    }
    let responses = e.drain();
    let agg = e.aggregated_metrics();
    let shed = agg.admission.shed_requests.get();
    assert_eq!(shed, (n / 5) as u64, "exactly the expired requests shed");
    assert_eq!(
        responses.len() as u64 + shed,
        submitted,
        "submitted = completed + shed"
    );
    assert_eq!(agg.native_requests.get(), responses.len() as u64);
    // Responses come back in submission order with correct checksums.
    let mut last_id = None;
    for r in &responses {
        if let Some(prev) = last_id {
            assert!(prev < r.id, "response order: {prev} before {}", r.id);
        }
        last_id = Some(r.id);
        let i = r.id as usize;
        let want = run_native_kernel(kernels[i % kernels.len()], &g, (i % 8) as u32);
        assert_eq!(
            r.result,
            relic_smt::coordinator::RequestResult::Native(want),
            "request {i} checksum"
        );
    }
}
