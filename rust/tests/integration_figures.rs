//! Integration: regenerate the paper's figures on the simulated SMT
//! core and assert the qualitative claims the paper makes — the
//! reproduction's "shape" contract (DESIGN.md §4.3).

use relic_smt::bench::{figures, geomean, KERNEL_NAMES};
use relic_smt::smtsim::CoreConfig;

fn cells_for<'a>(cells: &'a [figures::Cell], rt: &str) -> Vec<&'a figures::Cell> {
    cells.iter().filter(|c| c.runtime == rt).collect()
}

#[test]
fn figures_reproduce_paper_shape() {
    let cfg = CoreConfig::default();
    let f1 = figures::fig1(&cfg);
    let f3 = figures::fig3(&cfg);

    // Every (kernel, runtime) cell exists.
    assert_eq!(f1.len(), 7 * KERNEL_NAMES.len());
    assert_eq!(f3.len(), KERNEL_NAMES.len());

    // Claim 1 (Fig. 3): Relic parallelizes every kernel without
    // degradation.
    for c in &f3 {
        assert!(c.speedup > 1.0, "relic degrades {}: {:.3}", c.kernel, c.speedup);
    }

    // Claim 2 (Fig. 4 headline): Relic beats every baseline on the
    // no-negative-outliers average.
    let f4 = figures::fig4(&f1, &f3);
    let relic = f4.iter().find(|r| r.runtime == "relic").unwrap().value;
    for row in &f4 {
        if row.runtime != "relic" {
            assert!(
                relic > row.value,
                "relic {relic:.3} must beat {} {:.3}",
                row.runtime,
                row.value
            );
        }
    }

    // Claim 3 (§V): GNU OpenMP has the worst geomean (−17.7% in the
    // paper) and degrades overall.
    let geo = figures::section5_geomeans(&f1);
    let gnu = geo.iter().find(|r| r.runtime == "gnu-openmp").unwrap().value;
    for row in &geo {
        assert!(gnu <= row.value + 1e-9, "gnu not worst: vs {}", row.runtime);
    }
    assert!(gnu < 1.0, "gnu should degrade overall: {gnu:.3}");

    // Claim 4: GNU OpenMP accelerates the coarse PR/SSSP kernels
    // despite losing overall (paper Fig. 1: every framework wins on
    // PR and SSSP).
    for c in cells_for(&f1, "gnu-openmp") {
        if c.kernel == "pr" || c.kernel == "sssp" {
            assert!(c.speedup > 1.0, "gnu should win {}: {:.3}", c.kernel, c.speedup);
        }
    }

    // Claim 5: per kernel, Relic is at or above the best baseline for
    // the paper's headline kernels (BC, CC, PR, SSSP, JSON).
    for kernel in ["bc", "cc", "pr", "sssp", "json"] {
        let best_baseline = f1
            .iter()
            .filter(|c| c.kernel == kernel)
            .map(|c| c.speedup)
            .fold(f64::MIN, f64::max);
        let relic = f3.iter().find(|c| c.kernel == kernel).unwrap().speedup;
        // 1.5% slack: deterministic-mispredict phase alignment makes
        // individual cells noisy at the sub-percent level.
        assert!(
            relic >= 0.985 * best_baseline,
            "{kernel}: relic {relic:.3} below best baseline {best_baseline:.3}"
        );
    }

    // Claim 6: speedups never exceed the 2-task bound.
    for c in f1.iter().chain(&f3) {
        assert!(c.speedup < 2.05, "{}/{} impossible speedup", c.kernel, c.runtime);
    }

    // Claim 7: geomean figures are internally consistent.
    let manual: f64 = geomean(
        cells_for(&f1, "llvm-openmp").iter().map(|c| c.speedup),
    );
    let reported = geo.iter().find(|r| r.runtime == "llvm-openmp").unwrap().value;
    assert!((manual - reported).abs() < 1e-12);
}

#[test]
fn granularity_matches_paper_within_tolerance() {
    let cfg = CoreConfig::default();
    for row in figures::granularity(&cfg) {
        let rel = (row.micros - row.paper_micros).abs() / row.paper_micros;
        assert!(
            rel < 0.08,
            "{}: {:.2}µs vs paper {:.2}µs",
            row.kernel,
            row.micros,
            row.paper_micros
        );
    }
}

#[test]
fn determinism_across_processes_worth_of_state() {
    // Two full regenerations agree bit-for-bit (the sim is deterministic).
    let cfg = CoreConfig::default();
    let a = figures::fig3(&cfg);
    let b = figures::fig3(&cfg);
    assert_eq!(a, b);
}
