//! Integration: the admission-controlled engine keeps the sharded
//! engine's core invariant — accepted requests are never dropped and
//! never reordered — across every submit flavor (blocking, try,
//! parked) and every shed policy, and its counters reconcile exactly
//! with what was submitted and completed.

use std::time::{Duration, Instant};

use relic_smt::coordinator::{
    run_native_kernel, Admission, AdmissionConfig, Coordinator, Deadline, Engine, EngineConfig,
    GraphKernel, Request, Router, RouterConfig, ShedPolicy,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::relic::PoolConfig;

/// Unpinned engine: CI containers may refuse affinity syscalls.
fn engine(
    shards: usize,
    channel_capacity: usize,
    max_batch: usize,
    admission: AdmissionConfig,
) -> Engine {
    Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(shards),
            pin: false,
            channel_capacity,
            max_batch,
            ..PoolConfig::default()
        },
        admission,
        ..EngineConfig::default()
    })
}

fn req(id: u64, kernel: GraphKernel, source: u32) -> Request {
    Request {
        id,
        kernel,
        graph: paper_graph(),
        source,
        deadline: Deadline::none(),
    }
}

/// Mixed batch cycling every kernel over several sources.
fn mixed_batch(n: usize) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..n)
        .map(|i| req(i as u64, kernels[i % kernels.len()], (i % 8) as u32))
        .collect()
}

#[test]
fn never_policy_degenerates_to_pr2_blocking_behavior() {
    // Same capacity-1 backpressure regime as PR 2's test, explicit
    // ShedPolicy::Never: identical responses to the single-pair
    // coordinator, zero admission activity, stalls still counted.
    let mut single = Coordinator::with_parts(Router::new(RouterConfig::default(), None), None);
    let want = single.process_batch(mixed_batch(24));
    let mut e = engine(1, 1, 1, AdmissionConfig { shed: ShedPolicy::Never, ..Default::default() });
    let got = e.process_batch(mixed_batch(24));
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.backend, w.backend);
        assert_eq!(g.result, w.result);
    }
    let agg = e.aggregated_metrics();
    assert_eq!(agg.admission.shed_requests.get(), 0);
    assert_eq!(agg.admission.parked_submits.get(), 0);
    assert_eq!(agg.admission.queue_full_rejections.get(), 0);
    assert_eq!(agg.admission.deadline_misses.get(), 0);
    assert_eq!(agg.admission.slack_at_admission.count(), 0);
    assert!(
        e.pool_snapshot().backpressure_stalls > 0,
        "capacity-1 blocking admission still counts its stalls"
    );
}

#[test]
fn accepted_requests_never_dropped_or_reordered_under_queuefull_churn() {
    // Capacity-1 channels on 2 shards + an open-loop try_submit driver:
    // most submissions bounce at least once; every bounced request is
    // retried (bounded) and then parked, so everything is eventually
    // accepted — and must come back complete, in order, with correct
    // checksums.
    let g = paper_graph();
    let n = 96usize;
    let expected: Vec<u64> = mixed_batch(n)
        .iter()
        .map(|r| run_native_kernel(r.kernel, &g, r.source))
        .collect();
    let mut e = engine(2, 1, 1, AdmissionConfig::default());
    let mut bounces = 0u64;
    for mut r in mixed_batch(n) {
        let id = r.id;
        let mut attempts = 0;
        loop {
            match e.try_submit(r) {
                Admission::Accepted { .. } => break,
                Admission::QueueFull { rejected } => {
                    bounces += 1;
                    attempts += 1;
                    assert_eq!(rejected.id, id, "bounced request comes back unchanged");
                    if attempts > 64 {
                        // Guaranteed-progress fallback: park until the
                        // shard frees capacity.
                        assert!(e.submit_or_park(rejected).is_accepted());
                        break;
                    }
                    r = rejected;
                    std::thread::yield_now();
                }
                _ => unreachable!("Never policy cannot shed, healthy shards cannot degrade"),
            }
        }
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "every accepted request completes");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "acceptance order preserved");
        assert_eq!(
            r.result,
            relic_smt::coordinator::RequestResult::Native(expected[i]),
            "request {i} checksum"
        );
    }
    let agg = e.aggregated_metrics();
    assert_eq!(agg.admission.queue_full_rejections.get(), bounces);
    assert!(
        bounces > 0,
        "capacity-1 channels under an open-loop driver must bounce at least once"
    );
}

#[test]
fn shed_and_miss_counters_reconcile_with_submitted_minus_completed() {
    let mut e = engine(
        1,
        64,
        32,
        AdmissionConfig { shed: ShedPolicy::PastDeadline, ..Default::default() },
    );
    let submitted = 30usize;
    let mut shed_ids = Vec::new();
    for (i, mut r) in mixed_batch(submitted).into_iter().enumerate() {
        // Every third request arrives already expired.
        r.deadline = if i % 3 == 0 {
            Deadline::at(Instant::now())
        } else {
            Deadline::within(Duration::from_secs(3600))
        };
        match e.submit(r) {
            Admission::Shed { request, .. } => shed_ids.push(request.id),
            verdict => assert!(verdict.is_accepted()),
        }
    }
    let responses = e.drain();
    let agg = e.aggregated_metrics();
    // Reconciliation: submitted = completed + shed, exactly.
    assert_eq!(shed_ids.len(), submitted.div_ceil(3), "every third request shed");
    assert_eq!(responses.len() + shed_ids.len(), submitted);
    assert_eq!(agg.admission.shed_requests.get(), shed_ids.len() as u64);
    assert_eq!(agg.admission.shed_past_deadline.get(), shed_ids.len() as u64);
    assert_eq!(agg.native_requests.get(), responses.len() as u64);
    assert_eq!(
        agg.native_latency.count(),
        responses.len() as u64,
        "one latency sample per completed request"
    );
    // The generous deadlines were met: no misses; slack recorded for
    // every accepted (deadlined) request.
    assert_eq!(agg.admission.deadline_misses.get(), 0);
    assert_eq!(agg.admission.slack_at_admission.count(), responses.len() as u64);
    // Shed requests produce no response, and the survivors keep order.
    for pair in responses.windows(2) {
        assert!(pair[0].id < pair[1].id, "shedding must not reorder survivors");
    }
    for r in &responses {
        assert!(!shed_ids.contains(&r.id), "shed request {} must not complete", r.id);
    }
}

#[test]
fn deadline_misses_count_late_completions() {
    // Never-policy engine: expired deadlines are still admitted, so
    // every completion is late — misses == completions, and shed == 0.
    let mut e = engine(1, 64, 32, AdmissionConfig::default());
    let n = 12usize;
    for mut r in mixed_batch(n) {
        r.deadline = Deadline::at(Instant::now());
        assert!(e.submit(r).is_accepted());
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n);
    let agg = e.aggregated_metrics();
    assert_eq!(agg.admission.deadline_misses.get(), n as u64);
    assert_eq!(agg.admission.shed_requests.get(), 0);
}

#[test]
fn parked_producer_always_wakes_under_capacity_1_stress() {
    // The lost-wakeup stress: a tight submit_or_park loop against
    // capacity-1 channels. Requests are pre-built so the producer is
    // strictly faster than the µs-scale kernels draining the channel —
    // parking is guaranteed, and a lost wakeup would hang the test.
    let g = paper_graph();
    let n = 200usize;
    let expected: Vec<u64> = mixed_batch(n)
        .iter()
        .map(|r| run_native_kernel(r.kernel, &g, r.source))
        .collect();
    let mut e = engine(1, 1, 1, AdmissionConfig::default());
    let requests = mixed_batch(n);
    for r in requests {
        assert!(e.submit_or_park(r).is_accepted(), "park path always accepts");
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "nothing lost across park/wake cycles");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "FIFO preserved through parking");
        assert_eq!(
            r.result,
            relic_smt::coordinator::RequestResult::Native(expected[i])
        );
    }
    let agg = e.aggregated_metrics();
    let snap = e.pool_snapshot();
    assert!(
        agg.admission.parked_submits.get() > 0,
        "a capacity-1 channel under a pre-built burst must park at least once"
    );
    assert_eq!(
        agg.admission.parked_submits.get(),
        snap.parked_submits,
        "engine- and pool-level park counters agree"
    );
}

#[test]
fn queue_full_hands_the_request_back_intact() {
    let mut e = engine(1, 1, 1, AdmissionConfig::default());
    // Drive try_submit until one bounces; the bounce must carry the
    // same request (id intact), and resubmitting it must succeed.
    let mut bounced = None;
    for i in 0..10_000u64 {
        match e.try_submit(req(i, GraphKernel::Bfs, 0)) {
            Admission::QueueFull { rejected } => {
                assert_eq!(rejected.id, i, "bounced request comes back unchanged");
                bounced = Some(rejected);
                break;
            }
            verdict => assert!(verdict.is_accepted()),
        }
    }
    let bounced = bounced.expect("capacity-1 channel must fill within 10k submits");
    assert!(e.submit_or_park(bounced).is_accepted());
    let responses = e.drain();
    assert!(!responses.is_empty());
    // Acceptance order: strictly increasing ids, no duplicates.
    for pair in responses.windows(2) {
        assert!(pair[0].id < pair[1].id);
    }
}
