//! Integration: fault isolation end to end. Scripted failures —
//! kernel panics, killed shard threads, wedged shards, every shard
//! quarantined at once — must stay contained inside their failure
//! domain while the engine keeps its core invariant: every submitted
//! request gets exactly one response, executed at most once, in
//! acceptance order. The degenerate cases (no faults, supervisor on or
//! off) must be bitwise-identical to the pre-supervision engine.

use std::sync::Arc;
use std::time::Duration;

use relic_smt::coordinator::{
    run_native_kernel, Coordinator, Deadline, Engine, EngineConfig, GraphKernel, Request,
    RequestResult, Router, RouterConfig,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::relic::{FaultKind, FaultPlan, PoolConfig, SupervisorConfig};

/// Unpinned supervised engine (CI containers may refuse affinity
/// syscalls) with an optional fault plan and a test-scale watchdog.
fn chaos_engine(shards: usize, fault: Option<Arc<FaultPlan>>, stuck_after_ms: u64) -> Engine {
    Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(shards),
            pin: false,
            fault,
            ..PoolConfig::default()
        },
        supervisor: SupervisorConfig {
            stuck_after: Duration::from_millis(stuck_after_ms),
            ..SupervisorConfig::default()
        },
        ..EngineConfig::default()
    })
}

/// Mixed stream cycling every kernel over several sources.
fn mixed_batch(n: usize) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..n)
        .map(|i| Request {
            id: i as u64,
            kernel: kernels[i % kernels.len()],
            graph: paper_graph(),
            source: (i % 8) as u32,
            deadline: Deadline::none(),
        })
        .collect()
}

/// Serial checksums for [`mixed_batch`], indexed by request id.
fn expected_checksums(n: usize) -> Vec<u64> {
    let g = paper_graph();
    mixed_batch(n).iter().map(|r| run_native_kernel(r.kernel, &g, r.source)).collect()
}

#[test]
fn contained_panic_fails_one_request_and_reconciles() {
    // The injected panic targets the stream's first TC execution.
    // Exactly that request fails typed; its pair partner, its batch,
    // and its shard all survive, and the books balance: submitted =
    // ok + failed, with one completion recorded per ok request.
    let n = 24usize;
    let fault = Arc::new(FaultPlan::new().with_panic_on("tc", 1));
    // Production-scale watchdog: this test exercises containment, not
    // the supervisor, and must not trip it.
    let mut e = chaos_engine(2, Some(fault), 200);
    let want = expected_checksums(n);
    for r in mixed_batch(n) {
        assert!(e.submit(r).is_accepted());
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "one response per submitted request");
    let mut failed = 0u64;
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "acceptance order preserved through the failure");
        match r.result {
            RequestResult::Failed(kind) => {
                assert_eq!(kind, FaultKind::Panic);
                failed += 1;
            }
            _ => assert_eq!(r.result, RequestResult::Native(want[i])),
        }
    }
    assert_eq!(failed, 1, "exactly the panicking request fails");
    let agg = e.aggregated_metrics();
    assert_eq!(agg.fault.panics_caught.get(), 1);
    assert_eq!(agg.native_requests.get(), n as u64 - 1, "failures are not completions");
    assert_eq!(agg.native_latency.count(), n as u64 - 1);
    // The engine is still healthy: the one-shot fault is spent.
    let again = e.process_batch(mixed_batch(n));
    assert_eq!(again.len(), n);
    assert!(again.iter().all(|r| r.result.is_ok()), "the fault was one-shot");
}

#[test]
fn killed_shard_is_respawned_and_nothing_is_lost_or_duplicated() {
    // Shard 0's thread exits before its first batch (the batch is
    // requeued on the way out). The watchdog must classify it Dead,
    // quarantine it, steal + redirect its queue, and respawn it within
    // the restart budget — with every request executed exactly once.
    let n = 16usize;
    let fault = Arc::new(FaultPlan::new().with_kill(0, 1));
    let mut e = chaos_engine(2, Some(fault), 40);
    let want = expected_checksums(n);
    for r in mixed_batch(n) {
        assert!(e.submit(r).is_accepted());
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "a dead shard loses no requests");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "order survives steal + redirect");
        assert_eq!(r.result, RequestResult::Native(want[i]), "request {i} checksum");
    }
    let agg = e.aggregated_metrics();
    assert!(agg.fault.shard_restarts.get() >= 1, "the dead shard was respawned");
    assert!(agg.fault.watchdog_trips.get() >= 1, "death was detected by the watchdog");
    assert_eq!(agg.native_requests.get(), n as u64, "each request executed exactly once");
    // The respawned shard serves follow-up traffic.
    let again = e.process_batch(mixed_batch(8));
    assert_eq!(again.len(), 8);
    assert!(again.iter().all(|r| r.result.is_ok()));
}

#[test]
fn stalled_shard_is_quarantined_and_queued_work_redirected_at_most_once() {
    // Shard 0 wedges for 300 ms on its first batch — far past the
    // 40 ms stuck-after. The watchdog quarantines it and steals its
    // queued-but-unprocessed requests for redirection. The stolen set
    // and the stalled batch are disjoint by queue mutual exclusion, so
    // when the stall clears and the batch completes, every request has
    // executed exactly once.
    let n = 24usize;
    let fault = Arc::new(FaultPlan::new().with_stall(0, 1, Duration::from_millis(300)));
    let mut e = chaos_engine(2, Some(fault), 40);
    let want = expected_checksums(n);
    for r in mixed_batch(n) {
        assert!(e.submit(r).is_accepted());
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "a wedged shard loses no requests");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "no duplicates, no reordering");
        assert_eq!(r.result, RequestResult::Native(want[i]), "request {i} checksum");
    }
    let agg = e.aggregated_metrics();
    assert!(agg.fault.watchdog_trips.get() >= 1, "the stall tripped the watchdog");
    assert_eq!(
        agg.native_requests.get(),
        n as u64,
        "steal/redirect is at-most-once: exactly one execution per request"
    );
    assert_eq!(agg.fault.panics_caught.get(), 0);
    assert_eq!(agg.fault.responses_lost.get(), 0);
}

#[test]
fn all_shards_quarantined_degrades_to_inline_serial_with_identical_results() {
    // Every shard quarantined at once: the engine must keep answering
    // by running requests inline, serially, on the admission thread —
    // and the responses must match the single-pair coordinator's
    // result-for-result.
    let n = 12usize;
    let mut single = Coordinator::with_parts(Router::new(RouterConfig::default(), None), None);
    let want = single.process_batch(mixed_batch(n));
    let mut e = chaos_engine(2, None, 200);
    for s in 0..e.shard_count() {
        e.set_quarantined(s, true);
    }
    assert_eq!(e.quarantined_count(), 2);
    for r in mixed_batch(n) {
        let verdict = e.submit(r);
        assert!(verdict.is_degraded(), "all-quarantined must degrade");
        assert!(verdict.is_accepted(), "degraded requests still owe a response");
        assert_eq!(verdict.shard(), None, "no shard owns an inline request");
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n);
    for (got, expect) in responses.iter().zip(&want) {
        assert_eq!(got.id, expect.id);
        assert_eq!(got.backend, expect.backend);
        assert_eq!(got.result, expect.result, "degraded mode is checksum-identical");
    }
    let agg = e.aggregated_metrics();
    assert_eq!(agg.fault.degraded_requests.get(), n as u64);
    assert_eq!(agg.native_requests.get(), n as u64, "inline completions are recorded");
    // Releasing one shard restores normal sharded service.
    e.set_quarantined(0, false);
    let again = e.process_batch(mixed_batch(6));
    assert_eq!(again.len(), 6);
    assert!(again.iter().all(|r| r.result.is_ok()));
    assert_eq!(e.aggregated_metrics().fault.degraded_requests.get(), n as u64);
}

#[test]
fn no_faults_is_bitwise_identical_with_supervisor_on_or_off() {
    // The degeneracy ladder: with no fault plan, a supervised engine,
    // an unsupervised engine, and the single-pair coordinator must all
    // produce identical (id, backend, result) streams — supervision is
    // pure insurance, invisible until something actually fails.
    let n = 24usize;
    let mut single = Coordinator::with_parts(Router::new(RouterConfig::default(), None), None);
    let want = single.process_batch(mixed_batch(n));
    let mut supervised = chaos_engine(1, None, 200);
    let mut unsupervised = Engine::new(EngineConfig {
        pool: PoolConfig { shards: Some(1), pin: false, ..PoolConfig::default() },
        supervisor: SupervisorConfig { enabled: false, ..SupervisorConfig::default() },
        ..EngineConfig::default()
    });
    assert!(supervised.supervisor_enabled());
    assert!(!unsupervised.supervisor_enabled());
    let a = supervised.process_batch(mixed_batch(n));
    let b = unsupervised.process_batch(mixed_batch(n));
    assert_eq!(a.len(), want.len());
    assert_eq!(b.len(), want.len());
    for ((x, y), expect) in a.iter().zip(&b).zip(&want) {
        assert_eq!(x.id, expect.id);
        assert_eq!(y.id, expect.id);
        assert_eq!(x.backend, expect.backend);
        assert_eq!(y.backend, expect.backend);
        assert_eq!(x.result, expect.result);
        assert_eq!(y.result, expect.result);
    }
    // No recovery machinery fired on either engine, and only the
    // supervised engine advertises its watchdog.
    for e in [&supervised, &unsupervised] {
        let agg = e.aggregated_metrics();
        assert!(agg.fault.is_quiet(), "healthy runs leave every fault counter at zero");
    }
    assert!(supervised.report().contains("supervisor: on"));
    assert!(!unsupervised.report().contains("supervisor:"));
    assert!(!supervised.report().contains("faults:"), "quiet counters stay out of reports");
}
