//! Integration: the high-availability layer end to end. At-least-once
//! replay must recover injected failures without breaking the no-drop
//! invariant or the degeneracy ladder (replay off ≡ the at-most-once
//! engine, bit for bit); the budget-exhausted policies must flush, not
//! drop; deadline-expired failures must shed, never replay; and the
//! health surface must agree with what the supervisor actually decided.

use std::sync::Arc;
use std::time::Duration;

use relic_smt::coordinator::{
    run_native_kernel, Deadline, Engine, EngineConfig, GraphKernel, ReliabilityConfig, Request,
    RequestResult,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::relic::{BudgetPolicy, FaultKind, FaultPlan, PoolConfig, SupervisorConfig};

/// Unpinned supervised engine (CI containers may refuse affinity
/// syscalls) with an optional fault plan, a test-scale watchdog, and
/// replay on or off.
fn ha_engine(
    shards: usize,
    fault: Option<Arc<FaultPlan>>,
    stuck_after_ms: u64,
    replay: bool,
) -> Engine {
    Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(shards),
            pin: false,
            fault,
            ..PoolConfig::default()
        },
        supervisor: SupervisorConfig {
            stuck_after: Duration::from_millis(stuck_after_ms),
            ..SupervisorConfig::default()
        },
        reliability: ReliabilityConfig { replay, ..ReliabilityConfig::default() },
        ..EngineConfig::default()
    })
}

/// Mixed stream cycling every kernel over several sources.
fn mixed_batch(n: usize) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..n)
        .map(|i| Request {
            id: i as u64,
            kernel: kernels[i % kernels.len()],
            graph: paper_graph(),
            source: (i % 8) as u32,
            deadline: Deadline::none(),
        })
        .collect()
}

/// Serial checksums for [`mixed_batch`], indexed by request id.
fn expected_checksums(n: usize) -> Vec<u64> {
    let g = paper_graph();
    mixed_batch(n).iter().map(|r| run_native_kernel(r.kernel, &g, r.source)).collect()
}

#[test]
fn replay_recovers_injected_failures_and_reconciles_books() {
    // One caught panic and one dropped response, both one-shot. With
    // replay on, both requests must come back as verified successes —
    // the consumed injections cannot re-fire on the retry — and the
    // books must balance: every failure resolved by exactly one
    // recorded replay success, nothing shed, nothing given up.
    let n = 24usize;
    let fault = Arc::new(FaultPlan::new().with_panic_on("tc", 1).with_drop_response(0, 1));
    let mut e = ha_engine(2, Some(fault), 200, true);
    let want = expected_checksums(n);
    for r in mixed_batch(n) {
        assert!(e.submit(r).is_accepted());
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "one response per submitted request, replay included");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "acceptance order survives replay");
        assert_eq!(
            r.result,
            RequestResult::Native(want[i]),
            "request {i} recovered with the serial checksum"
        );
    }
    let agg = e.aggregated_metrics();
    assert_eq!(agg.fault.panics_caught.get(), 1, "the panic was injected and caught");
    assert_eq!(agg.fault.responses_lost.get(), 1, "the drop was injected and synthesized");
    assert_eq!(
        agg.reliability.replay_successes.get(),
        2,
        "each injected failure was recovered by replay"
    );
    assert!(agg.reliability.replays.get() >= 2, "at least one attempt per failure");
    assert_eq!(agg.reliability.replay_sheds.get(), 0);
    assert_eq!(agg.reliability.gave_up.get(), 0);
    // At-least-once means the dropped response's work ran twice; the
    // completion count reflects the re-execution, never fewer than one
    // completion per request.
    assert!(agg.native_requests.get() >= n as u64);
    assert!(e.report().contains("reliability:"), "active counters surface in the report");
}

#[test]
fn replay_off_is_bitwise_identical_to_the_at_most_once_engine() {
    // The degeneracy ladder, both rungs. Under a fault with replay off,
    // the typed failure surfaces exactly as the pre-replay engine
    // surfaced it and the reliability counters stay silent. With no
    // fault, replay on and replay off produce identical
    // (id, backend, result) streams — retention is invisible until
    // something actually fails.
    let n = 24usize;
    let fault = Arc::new(FaultPlan::new().with_panic_on("tc", 1));
    let mut off = ha_engine(2, Some(fault), 200, false);
    let want = expected_checksums(n);
    let responses = off.process_batch(mixed_batch(n));
    assert_eq!(responses.len(), n);
    let mut failed = 0u64;
    for (i, r) in responses.iter().enumerate() {
        match r.result {
            RequestResult::Failed(kind) => {
                assert_eq!(kind, FaultKind::Panic);
                failed += 1;
            }
            _ => assert_eq!(r.result, RequestResult::Native(want[i])),
        }
    }
    assert_eq!(failed, 1, "replay off surfaces the typed failure untouched");
    let agg = off.aggregated_metrics();
    assert!(agg.reliability.is_quiet(), "replay off never touches the replay books");
    assert!(!off.report().contains("reliability:"), "quiet counters stay out of reports");

    let mut healthy_on = ha_engine(1, None, 200, true);
    let mut healthy_off = ha_engine(1, None, 200, false);
    let a = healthy_on.process_batch(mixed_batch(n));
    let b = healthy_off.process_batch(mixed_batch(n));
    assert_eq!(a.len(), n);
    assert_eq!(b.len(), n);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.backend, y.backend);
        assert_eq!(x.result, y.result, "replay on a healthy run is invisible");
    }
    assert!(healthy_on.aggregated_metrics().reliability.is_quiet());
}

#[test]
fn drain_and_exit_flushes_queued_work_with_typed_verdicts() {
    // Shard 0 dies with a zero restart budget and the policy set to
    // drain_and_exit. The engine must finish the drain — every queued
    // request resolved with a typed verdict, nothing dropped on the
    // floor — and only then raise the exit request for the CLI to map
    // to a nonzero exit.
    let n = 16usize;
    let fault = Arc::new(FaultPlan::new().with_kill(0, 1));
    let mut e = Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(2),
            pin: false,
            fault: Some(fault),
            ..PoolConfig::default()
        },
        supervisor: SupervisorConfig {
            stuck_after: Duration::from_millis(40),
            max_restarts: 0,
            on_budget_exhausted: BudgetPolicy::DrainAndExit,
            ..SupervisorConfig::default()
        },
        ..EngineConfig::default()
    });
    let want = expected_checksums(n);
    for r in mixed_batch(n) {
        assert!(e.submit(r).is_accepted());
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "drain_and_exit flushes, it does not drop");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "flush preserves acceptance order");
        match r.result {
            RequestResult::Failed(_) => {} // a typed verdict is a flush, not a loss
            _ => assert_eq!(r.result, RequestResult::Native(want[i]), "request {i} checksum"),
        }
    }
    assert!(e.exit_requested(), "budget exhaustion under drain_and_exit requests exit");
    let report = e.health();
    assert!(!report.live, "an exit-requested engine is not live");
    assert!(!report.ready, "and must not receive new traffic");
    assert!(report.exit_requested);
    assert_eq!(report.on_budget_exhausted, "drain_and_exit");
}

#[test]
fn expired_deadline_failures_are_shed_not_replayed() {
    // A request whose deadline has already passed cannot be saved by a
    // retry. With replay on and a panic injected into a stream whose
    // deadlines are all expired at submission (shed policy `never`
    // still admits them), the failed request must surface typed and be
    // counted as a replay shed — zero replay attempts launched.
    let n = 12usize;
    let fault = Arc::new(FaultPlan::new().with_panic_on("tc", 1));
    let mut e = ha_engine(2, Some(fault), 200, true);
    let want = expected_checksums(n);
    let kernels = GraphKernel::all();
    for i in 0..n {
        let verdict = e.submit(Request {
            id: i as u64,
            kernel: kernels[i % kernels.len()],
            graph: paper_graph(),
            source: (i % 8) as u32,
            deadline: Deadline::within(Duration::ZERO),
        });
        assert!(verdict.is_accepted(), "shed policy `never` admits expired deadlines");
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n);
    let mut failed = 0u64;
    for (i, r) in responses.iter().enumerate() {
        match r.result {
            RequestResult::Failed(kind) => {
                assert_eq!(kind, FaultKind::Panic);
                failed += 1;
            }
            _ => assert_eq!(r.result, RequestResult::Native(want[i])),
        }
    }
    assert_eq!(failed, 1, "the expired request surfaces its typed failure");
    let agg = e.aggregated_metrics();
    assert_eq!(agg.reliability.replay_sheds.get(), 1, "counted as a deadline shed");
    assert_eq!(agg.reliability.replays.get(), 0, "retrying cannot un-miss a deadline");
    assert_eq!(agg.reliability.replay_successes.get(), 0);
    assert_eq!(agg.reliability.gave_up.get(), 0);
}

#[test]
fn health_report_agrees_with_supervisor_verdicts() {
    // Kill shard 0 with a zero restart budget under the default
    // quarantine policy: the health surface must tell the same story
    // the supervisor's verdicts told — one dead, quarantined shard with
    // no credits left, one healthy shard still serving, engine live and
    // ready, counters equal to the aggregated fault metrics.
    let n = 16usize;
    let fault = Arc::new(FaultPlan::new().with_kill(0, 1));
    let mut e = Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(2),
            pin: false,
            fault: Some(fault),
            ..PoolConfig::default()
        },
        supervisor: SupervisorConfig {
            stuck_after: Duration::from_millis(40),
            max_restarts: 0,
            ..SupervisorConfig::default()
        },
        ..EngineConfig::default()
    });
    let responses = e.process_batch(mixed_batch(n));
    assert_eq!(responses.len(), n, "a dead shard with no budget still loses nothing");
    let report = e.health();
    assert!(report.live, "a quarantined shard does not kill the engine");
    assert!(report.ready, "the surviving shard keeps it ready");
    assert!(report.supervised);
    assert!(!report.exit_requested);
    assert_eq!(report.on_budget_exhausted, "quarantine");
    assert_eq!(report.max_restarts, 0);
    assert_eq!(report.shards.len(), 2);
    assert_eq!(
        report.quarantined,
        e.quarantined_count(),
        "the report's quarantine count is the engine's"
    );
    let dead = &report.shards[0];
    assert_eq!(dead.health, "dead", "shard 0's verdict is visible in its row");
    assert!(dead.quarantined, "and routing skips it");
    assert!(dead.quarantined_for_ms.is_some(), "with a measured quarantine age");
    assert_eq!(dead.restarts_remaining, 0, "no credits with a zero budget");
    let alive = &report.shards[1];
    assert!(!alive.quarantined, "the survivor serves unquarantined");
    let agg = e.aggregated_metrics();
    assert_eq!(report.watchdog_trips, agg.fault.watchdog_trips.get());
    assert_eq!(report.panics_caught, agg.fault.panics_caught.get());
    assert_eq!(report.shard_restarts, agg.fault.shard_restarts.get());
    assert_eq!(report.responses_lost, agg.fault.responses_lost.get());
    assert!(report.watchdog_trips >= 1, "the death was detected");
    assert_eq!(report.shard_restarts, 0, "a zero budget never respawns");
    // The serialized form carries the same verdicts for an external
    // orchestrator (compact JSON, stable key order).
    let json = report.to_json();
    assert!(json.contains("\"live\":true"));
    assert!(json.contains("\"ready\":true"));
    assert!(json.contains("\"health\":\"dead\""));
    assert!(json.contains("\"on_budget_exhausted\":\"quarantine\""));
}
