//! Integration: the sharded engine (`RelicPool` of pair-shards) is
//! behaviorally equivalent to the single-pair coordinator — same
//! checksums, complete and ordered responses under backpressure, sane
//! topology parsing.

use relic_smt::coordinator::{
    run_native_kernel, Backend, Coordinator, Deadline, Engine, EngineConfig, GraphKernel,
    Request, RequestResult, Router, RouterConfig,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::relic::pool::{
    discover_placements, fallback_pairs, sibling_pairs_from_lists, PoolConfig,
};

/// Unpinned engine: CI containers may refuse affinity syscalls.
fn engine(shards: usize, channel_capacity: usize, max_batch: usize) -> Engine {
    Engine::new(EngineConfig {
        pool: PoolConfig {
            shards: Some(shards),
            pin: false,
            channel_capacity,
            max_batch,
            ..PoolConfig::default()
        },
        ..EngineConfig::default()
    })
}

fn req(id: u64, kernel: GraphKernel, source: u32) -> Request {
    Request {
        id,
        kernel,
        graph: paper_graph(),
        source,
        deadline: Deadline::none(),
    }
}

/// Mixed batch cycling every kernel over several sources.
fn mixed_batch(n: usize) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..n)
        .map(|i| req(i as u64, kernels[i % kernels.len()], (i % 8) as u32))
        .collect()
}

#[test]
fn pool_checksums_equal_single_pair_for_every_kernel() {
    let g = paper_graph();
    let n = 36; // 6 per kernel, mixed sources
    let expected: Vec<u64> = mixed_batch(n)
        .iter()
        .map(|r| run_native_kernel(r.kernel, &g, r.source))
        .collect();
    for shards in [1usize, 2, 3] {
        let mut e = engine(shards, 64, 32);
        let responses = e.process_batch(mixed_batch(n));
        assert_eq!(responses.len(), n);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64, "submission order at shards={shards}");
            assert_eq!(r.backend, Backend::Native);
            assert_eq!(
                r.result,
                RequestResult::Native(expected[i]),
                "shards={shards} request {i}: pool checksum != single-pair"
            );
        }
    }
}

#[test]
fn one_shard_degenerates_to_single_pair_coordinator() {
    let mut single =
        Coordinator::with_parts(Router::new(RouterConfig::default(), None), None);
    let want = single.process_batch(mixed_batch(13));
    let mut e = engine(1, 64, 32);
    let got = e.process_batch(mixed_batch(13));
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.backend, w.backend);
        assert_eq!(g.result, w.result);
    }
    // All work landed on the one shard, every request natively served.
    let agg = e.aggregated_metrics();
    assert_eq!(agg.native_requests.get(), 13);
    let snap = e.pool_snapshot();
    assert_eq!(snap.shards, 1);
    assert_eq!(snap.occupancy, vec![13]);
}

#[test]
fn backpressure_drops_nothing_and_preserves_order() {
    // Capacity-1 channel + 1-request batches force admission stalls.
    let g = paper_graph();
    let mut e = engine(1, 1, 1);
    let n = 48;
    let expected: Vec<u64> = mixed_batch(n)
        .iter()
        .map(|r| run_native_kernel(r.kernel, &g, r.source))
        .collect();
    for r in mixed_batch(n) {
        let _ = e.submit(r);
    }
    let responses = e.drain();
    assert_eq!(responses.len(), n, "no request dropped under backpressure");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "no reordering under backpressure");
        assert_eq!(r.result, RequestResult::Native(expected[i]));
    }
    let snap = e.pool_snapshot();
    assert_eq!(snap.dispatched, n as u64);
    assert!(
        snap.backpressure_stalls > 0,
        "a capacity-1 channel fed 48 µs-scale kernels must stall at least once"
    );
}

#[test]
fn repeated_submit_drain_cycles_accumulate_metrics() {
    let mut e = engine(2, 64, 32);
    for round in 0..5u64 {
        for i in 0..6u64 {
            let _ = e.submit(req(round * 6 + i, GraphKernel::Bfs, 0));
        }
        let responses = e.drain();
        assert_eq!(responses.len(), 6);
    }
    let agg = e.aggregated_metrics();
    assert_eq!(agg.native_requests.get(), 30);
    assert_eq!(agg.native_latency.count(), 30, "one latency sample per request");
    assert_eq!(e.pool_snapshot().occupancy.iter().sum::<u64>(), 30);
}

#[test]
fn topology_fixtures_parse_like_sysfs() {
    // i7-8700-style 6-core/12-thread layout: siblings (i, i+6), each
    // pair listed from both CPUs.
    let lists: Vec<String> = (0..12)
        .map(|cpu| format!("{},{}\n", cpu % 6, cpu % 6 + 6))
        .collect();
    let pairs = sibling_pairs_from_lists(lists.iter().map(String::as_str));
    assert_eq!(pairs, (0..6).map(|i| (i, i + 6)).collect::<Vec<_>>());

    // Adjacent numbering in range form ("0-1"), as some hosts report.
    let pairs = sibling_pairs_from_lists(["0-1", "0-1", "2-3", "2-3"]);
    assert_eq!(pairs, vec![(0, 1), (2, 3)]);

    // SMT off: every list is a singleton — fallback pairing kicks in.
    let none = sibling_pairs_from_lists(["0", "1", "2", "3"]);
    assert!(none.is_empty());
    assert_eq!(fallback_pairs(4), vec![(0, 1), (2, 3)]);

    // Placement honors explicit shard counts even without topology.
    let placements = discover_placements(Some(2), false);
    assert_eq!(placements.len(), 2);
    assert!(placements.iter().all(|p| p.main_cpu.is_none()));
}
