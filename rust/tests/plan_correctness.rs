//! Plan / tuner correctness suite.
//!
//! The tentpole invariants of profile-guided adaptive execution:
//!
//! 1. **Plans change assignment, never results** — under every plan in
//!    the candidate lattice, every kernel's checksum through the full
//!    engine path is bitwise equal to serial, and the same holds for
//!    every plan the online tuner explores.
//! 2. **Degeneracy** — a config with no tuner and no forced plan (the
//!    default) is response-for-response the pre-plan engine: the
//!    planned dispatch branch is never taken.
//! 3. **Determinism** — the tuner's exploration sequence is a pure
//!    function of `(seed, request stream)`; wall-clock latencies feed
//!    only the greedy ranking, never the exploration order.

use relic_smt::coordinator::{
    run_native_kernel, Deadline, Engine, EngineConfig, GraphKernel, Request, RequestResult,
    TunerConfig,
};
use relic_smt::graph::kronecker::{kronecker_graph, paper_graph, KroneckerParams, PAPER_SEED};
use relic_smt::graph::CsrGraph;
use relic_smt::relic::{ExecutionPlan, PoolConfig};

fn base_config() -> EngineConfig {
    EngineConfig {
        pool: PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
        ..EngineConfig::default()
    }
}

/// Two requests per kernel so serial-planned arms always have a pairing
/// partner in the batch.
fn mixed_requests(graph: &CsrGraph, first_id: u64) -> Vec<Request> {
    let kernels = GraphKernel::all();
    (0..2 * kernels.len())
        .map(|i| Request {
            id: first_id + i as u64,
            kernel: kernels[i % kernels.len()],
            graph: graph.clone(),
            source: 0,
            deadline: Deadline::none(),
        })
        .collect()
}

fn expected_checksums(graph: &CsrGraph) -> Vec<u64> {
    GraphKernel::all().iter().map(|&k| run_native_kernel(k, graph, 0)).collect()
}

#[test]
fn every_lattice_plan_keeps_every_kernel_bitwise_equal_to_serial() {
    let g = paper_graph();
    let expected = expected_checksums(&g);
    for plan in ExecutionPlan::lattice() {
        let mut cfg = base_config();
        cfg.plan = Some(plan);
        let mut e = Engine::new(cfg);
        let responses = e.process_batch(mixed_requests(&g, 0));
        assert_eq!(responses.len(), 12, "plan {plan}: lost responses");
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(
                r.result,
                RequestResult::Native(expected[i % expected.len()]),
                "plan {plan}: {:?} checksum diverged from serial",
                GraphKernel::all()[i % expected.len()]
            );
        }
    }
}

#[test]
fn no_tuner_no_plan_config_is_response_for_response_the_default_engine() {
    // The degeneracy anchor: the default config carries neither a tuner
    // nor a forced plan, so the planned dispatch branch is never taken
    // and the response stream (ids, order, results) is the pre-plan
    // engine's.
    let default_cfg = EngineConfig::default();
    assert!(default_cfg.tuner.is_none() && default_cfg.plan.is_none());
    let g = kronecker_graph(&KroneckerParams::gap(7, 16, PAPER_SEED));
    let mut explicit_cfg = base_config();
    explicit_cfg.tuner = None;
    explicit_cfg.plan = None;
    let mut explicit = Engine::new(explicit_cfg);
    let mut default_engine = Engine::new(base_config());
    assert!(explicit.tuner().is_none() && default_engine.tuner().is_none());
    let sig = |responses: &[relic_smt::coordinator::Response]| -> Vec<(u64, RequestResult)> {
        responses.iter().map(|r| (r.id, r.result.clone())).collect()
    };
    for round in 0..4u64 {
        let a = explicit.process_batch(mixed_requests(&g, round * 100));
        let b = default_engine.process_batch(mixed_requests(&g, round * 100));
        assert_eq!(sig(&a), sig(&b), "round {round}: response-for-response identical");
    }
}

#[test]
fn tuner_resolves_per_shape_plans_and_every_explored_plan_matches_serial() {
    // Two graph sizes land in two shape classes (32 vertices -> n<64,
    // 128 vertices -> n<512), so the tuner keeps independent statistics
    // per (kernel, shape) cell. Every response along the way — quota
    // round-robin, exploration, greedy — is gated against serial.
    let small = paper_graph();
    let big = kronecker_graph(&KroneckerParams::gap(7, 16, PAPER_SEED));
    let expected_small = expected_checksums(&small);
    let expected_big = expected_checksums(&big);
    let mut cfg = base_config();
    cfg.tuner = Some(TunerConfig { epsilon: 0.0, min_samples: 1, ..TunerConfig::default() });
    let mut e = Engine::new(cfg);
    let rounds = ExecutionPlan::lattice().len() + 4;
    for round in 0..rounds {
        for (graph, expected) in [(&small, &expected_small), (&big, &expected_big)] {
            let responses = e.process_batch(mixed_requests(graph, round as u64 * 1000));
            assert_eq!(responses.len(), 12);
            for (i, r) in responses.iter().enumerate() {
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[i % expected.len()]),
                    "round {round}: explored plan diverged from serial"
                );
            }
        }
    }
    let tuner = e.tuner().expect("tuner installed");
    let rows = tuner.resolved();
    assert_eq!(rows.len(), 12, "6 kernels x 2 shape classes have samples: {rows:?}");
    for k in GraphKernel::all() {
        let shapes: Vec<usize> =
            rows.iter().filter(|r| r.kernel == k).map(|r| r.shape).collect();
        assert_eq!(shapes, [0, 1], "{k:?} tuned per shape class");
    }
    // Quota satisfied: every cell saw at least one sample per arm.
    let arms = ExecutionPlan::lattice().len() as u64;
    assert!(
        rows.iter().all(|r| r.samples >= arms),
        "every arm collected its forced sample: {rows:?}"
    );
}

#[test]
fn fixed_seed_exploration_sequences_are_deterministic() {
    // epsilon = 1.0: after the forced quota the tuner explores on every
    // settle tick, so the sequence of selected arms — and therefore the
    // per-arm sample counts and the finally-resolved plan — depends
    // only on the seed and the request stream, never on measured
    // wall-clock latencies.
    let g = paper_graph();
    let run = || -> Vec<(GraphKernel, usize, String, u64)> {
        let mut cfg = base_config();
        cfg.tuner =
            Some(TunerConfig { epsilon: 1.0, seed: 42, min_samples: 1, calibrate: false });
        let mut e = Engine::new(cfg);
        for round in 0..30u64 {
            let responses = e.process_batch(mixed_requests(&g, round * 100));
            assert_eq!(responses.len(), 12);
        }
        e.tuner()
            .expect("tuner installed")
            .resolved()
            .iter()
            .map(|r| (r.kernel, r.shape, r.plan.to_string(), r.samples))
            .collect()
    };
    let first = run();
    assert!(!first.is_empty());
    assert_eq!(first, run(), "identical seed + stream => identical selection sequence");
}
