//! Graph analytics across scales: the six GAP kernels on Kronecker
//! graphs from 32 to 4096 vertices, with fine-grained pairs co-scheduled
//! through Relic — the paper's "client analytics" motivating workload.
//!
//! Run: `cargo run --release --example graph_analytics [-- --max-scale 12]`

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use relic_smt::cli::Args;
use relic_smt::graph::{bc, bfs, cc, kronecker_graph, pr, sssp, tc, KroneckerParams};
use relic_smt::probe::NoProbe;
use relic_smt::relic::Relic;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_scale = args.get_u64("max-scale", 12) as u32;
    let relic = Relic::new();

    println!(
        "{:<7}{:>9}{:>9}{:>11}{:>11}{:>11}{:>11}{:>11}{:>11}",
        "scale", "verts", "edges", "bfs µs", "cc µs", "pr µs", "sssp µs", "tc µs", "bc µs"
    );
    for scale in [5u32, 8, 10, max_scale] {
        let g = kronecker_graph(&KroneckerParams::gap(scale, 16, 1));
        let time =
            |f: &dyn Fn() -> u64| -> (u64, f64) {
                let t0 = Instant::now();
                let checksum = f();
                (checksum, t0.elapsed().as_nanos() as f64 / 1000.0)
            };
        let (_, bfs_us) = time(&|| bfs::checksum(&bfs::bfs(&g, 0, &mut NoProbe)));
        let (_, cc_us) = time(&|| cc::checksum(&cc::shiloach_vishkin(&g, &mut NoProbe)));
        let (_, pr_us) = time(&|| {
            pr::checksum(&pr::pagerank(&g, pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe))
        });
        let (_, sssp_us) = time(&|| {
            sssp::checksum(&sssp::delta_stepping(&g, 0, sssp::DEFAULT_DELTA, &mut NoProbe))
        });
        let (_, tc_us) = time(&|| tc::triangle_count(&g, &mut NoProbe));
        let (_, bc_us) =
            time(&|| bc::checksum(&bc::brandes_single_source(&g, 0, &mut NoProbe)));
        println!(
            "{:<7}{:>9}{:>9}{:>11.1}{:>11.1}{:>11.1}{:>11.1}{:>11.1}{:>11.1}",
            scale,
            g.num_vertices(),
            g.num_edges(),
            bfs_us,
            cc_us,
            pr_us,
            sssp_us,
            tc_us,
            bc_us
        );
    }

    // Fine-grained scenario: a stream of per-request BFS tasks, paired
    // two at a time onto the SMT core via Relic (paper §VI-A).
    let g = kronecker_graph(&KroneckerParams::gap(5, 16, 1));
    let requests: Vec<u32> = (0..2000).map(|i| (i % 32) as u32).collect();
    let sink = AtomicU64::new(0);
    let t0 = Instant::now();
    for pair in requests.chunks(2) {
        let (a, b) = (pair[0], pair[1]);
        let task_b = || {
            sink.fetch_add(bfs::checksum(&bfs::bfs(&g, b, &mut NoProbe)), Ordering::Relaxed);
        };
        relic.pair(
            || {
                sink.fetch_add(
                    bfs::checksum(&bfs::bfs(&g, a, &mut NoProbe)),
                    Ordering::Relaxed,
                );
            },
            &task_b,
        );
    }
    let dt = t0.elapsed();
    println!(
        "\nrelic-paired BFS stream: {} requests in {:?} ({:.2} µs/request, checksum {})",
        requests.len(),
        dt,
        dt.as_nanos() as f64 / 1000.0 / requests.len() as f64,
        sink.load(Ordering::Relaxed)
    );
}
