//! Quickstart: the Relic framework in 60 lines.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Demonstrates the paper's API (§VI-A): `submit()` / `wait()` from the
//! main thread, the assistant thread executing tasks, and the
//! `wake_up_hint` / `sleep_hint` lifecycle — plus the two-instance
//! benchmark protocol on one real kernel.

use std::sync::atomic::{AtomicU64, Ordering};

use relic_smt::graph::{kronecker::paper_graph, tc};
use relic_smt::probe::NoProbe;
use relic_smt::relic::{affinity, Relic, RelicConfig, WaitPolicy};

fn main() {
    println!("host: {}", affinity::topology_summary());

    // 1. Start Relic (paper defaults: SPSC capacity 128, spin+pause).
    //    Pin the assistant to the SMT sibling when the host has one.
    let relic = Relic::with_config(RelicConfig {
        queue_capacity: 128,
        wait_policy: WaitPolicy::SpinPause,
        assistant_cpu: affinity::smt_sibling_pair().map(|(_, b)| b),
    });

    // 2. The C-style API: function pointer + argument.
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    fn routine(arg: usize) {
        COUNTER.fetch_add(arg as u64, Ordering::Relaxed);
    }
    for i in 0..100 {
        relic.submit(routine, i).expect("queue has room");
    }
    relic.wait();
    println!("submit/wait: counter = {} (expect 4950)", COUNTER.load(Ordering::Relaxed));

    // 3. The two-instance protocol from the paper's benchmarks: run two
    //    triangle-counting tasks, one on each logical thread.
    let g = paper_graph();
    let triangles = AtomicU64::new(0);
    relic.pair(
        || {
            triangles.fetch_add(tc::triangle_count(&g, &mut NoProbe), Ordering::Relaxed);
        },
        &|| {
            triangles.fetch_add(tc::triangle_count(&g, &mut NoProbe), Ordering::Relaxed);
        },
    );
    println!("two TC instances counted {} triangles total", triangles.load(Ordering::Relaxed));

    // 4. Long serial phase coming up? Park the assistant explicitly.
    relic.sleep_hint();
    let serial_work: u64 = (0..1_000_000u64).sum();
    relic.wake_up_hint();
    println!("serial phase done ({serial_work}); assistant re-armed");

    let stats = relic.stats();
    println!(
        "stats: submitted={} completed={} queue_full={}",
        stats.submitted, stats.completed, stats.queue_full_events
    );
}
