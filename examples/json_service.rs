//! JSON ingestion service: parse a stream of client documents with
//! fine-grained parallelism on one SMT core (the paper's §IV-B
//! scenario scaled to a service), reporting latency percentiles.
//!
//! Run: `cargo run --release --example json_service [-- --docs 20000]`

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use relic_smt::cli::Args;
use relic_smt::json;
use relic_smt::metrics::Histogram;
use relic_smt::relic::Relic;

/// Build a batch of synthetic client documents around the widget sample
/// (varying numeric payloads so parses aren't byte-identical).
fn documents(n: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|i| {
            let widget = String::from_utf8_lossy(json::WIDGET)
                .replace("500", &format!("{}", 100 + (i % 900)));
            widget.into_bytes()
        })
        .collect()
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n_docs = args.get_u64("docs", 20_000) as usize;
    let docs = documents(n_docs);
    let relic = Relic::new();

    // Serial baseline.
    let serial_nodes = AtomicU64::new(0);
    let t0 = Instant::now();
    for d in &docs {
        serial_nodes.fetch_add(
            json::parse(d).expect("valid doc").node_count() as u64,
            Ordering::Relaxed,
        );
    }
    let serial = t0.elapsed();

    // Paired: two documents at a time, one per logical thread.
    let paired_nodes = AtomicU64::new(0);
    let latency = Histogram::new();
    let t0 = Instant::now();
    for pair in docs.chunks(2) {
        let t = Instant::now();
        match pair {
            [a, b] => {
                let task_b = || {
                    paired_nodes.fetch_add(
                        json::parse(b).expect("valid doc").node_count() as u64,
                        Ordering::Relaxed,
                    );
                };
                relic.pair(
                    || {
                        paired_nodes.fetch_add(
                            json::parse(a).expect("valid doc").node_count() as u64,
                            Ordering::Relaxed,
                        );
                    },
                    &task_b,
                );
            }
            [a] => {
                paired_nodes.fetch_add(
                    json::parse(a).expect("valid doc").node_count() as u64,
                    Ordering::Relaxed,
                );
            }
            _ => unreachable!(),
        }
        latency.record(t.elapsed().as_nanos() as u64);
    }
    let paired = t0.elapsed();

    assert_eq!(
        serial_nodes.load(Ordering::Relaxed),
        paired_nodes.load(Ordering::Relaxed),
        "parallel parse must produce identical DOMs"
    );
    println!("documents:        {n_docs}");
    println!("DOM nodes total:  {}", serial_nodes.load(Ordering::Relaxed));
    println!(
        "serial:           {:?} ({:.2} µs/doc)",
        serial,
        serial.as_nanos() as f64 / 1000.0 / n_docs as f64
    );
    println!(
        "relic-paired:     {:?} ({:.2} µs/doc, speedup {:.3}x)",
        paired,
        paired.as_nanos() as f64 / 1000.0 / n_docs as f64,
        serial.as_nanos() as f64 / paired.as_nanos() as f64
    );
    println!("pair latency:     {}", latency.summary("ns"));
    println!("note: speedup >1 requires a real SMT host; see `repro fig3` for sim results");
}
