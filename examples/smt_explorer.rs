//! SMT design-space exploration with the simulator: how do Relic's
//! speedups respond to core parameters the paper could not vary on
//! fixed silicon? Sweeps wake latency (OS), pause latency (ISA), issue
//! width (µarch), and SMT fetch policy.
//!
//! Run: `cargo run --release --example smt_explorer`

use relic_smt::bench::Workload;
use relic_smt::smtsim::{self, CoreConfig, FetchPolicy};

fn geo(vals: &[f64]) -> f64 {
    (vals.iter().map(|v| v.ln()).sum::<f64>() / vals.len() as f64).exp()
}

fn geomean_speedup(runtime: &str, cfg: &CoreConfig) -> f64 {
    let vals: Vec<f64> = Workload::all()
        .iter()
        .map(|w| {
            let (a, b) = (w.trace(0, cfg), w.trace(1, cfg));
            smtsim::speedup(runtime, &a, &b, cfg)
        })
        .collect();
    geo(&vals)
}

fn main() {
    let base = CoreConfig::default();

    println!("geomean speedup across the 7 paper kernels (simulated)\n");

    println!("-- wake latency (futex) sensitivity: gnu-openmp vs relic --");
    for wake in [1_000u64, 2_500, 5_000, 10_000, 20_000] {
        let cfg = CoreConfig { wake_latency: wake, ..base };
        println!(
            "  wake={wake:>6}cy   gnu={:.3}   relic={:.3}",
            geomean_speedup("gnu-openmp", &cfg),
            geomean_speedup("relic", &cfg)
        );
    }

    println!("\n-- pause latency sensitivity (relic spins with pause) --");
    for pause in [5u64, 15, 30, 60, 120] {
        let cfg = CoreConfig { pause_latency: pause, ..base };
        println!("  pause={pause:>4}cy   relic={:.3}", geomean_speedup("relic", &cfg));
    }

    println!("\n-- issue width / SMT sharing --");
    for (w, per) in [(2u32, 2u32), (3, 2), (4, 3), (6, 4), (8, 6)] {
        let cfg = CoreConfig { issue_width: w, per_thread_issue: per, ..base };
        println!(
            "  width={w} per-thread={per}   relic={:.3}",
            geomean_speedup("relic", &cfg)
        );
    }

    println!("\n-- fetch policy --");
    for policy in [FetchPolicy::RoundRobin, FetchPolicy::Icount] {
        let cfg = CoreConfig { fetch: policy, ..base };
        println!("  {policy:?}: relic={:.3}", geomean_speedup("relic", &cfg));
    }
}
