//! END-TO-END DRIVER: the hybrid analytics service on a real workload.
//!
//! Run: `make artifacts && cargo run --release --example hybrid_pjrt`
//!
//! This exercises every layer of the system together:
//!   L1/L2  Pallas semiring kernels + JAX graph models, AOT-lowered to
//!          `artifacts/*.hlo.txt` by `make artifacts`;
//!   runtime  the Rust PJRT client loads + compiles the artifacts;
//!   L3     the coordinator routes a stream of analytics requests —
//!          PageRank/BFS/SSSP/CC/TC/BC over Kronecker graphs — to the
//!          PJRT backend (coarse, 32-vertex dense kernels) or to the
//!          native kernels paired on the SMT core via Relic (fine);
//!
//! and validates PJRT results against the native kernels before
//! reporting throughput and latency percentiles (recorded in
//! EXPERIMENTS.md §E2E).

use std::path::Path;
use std::time::Instant;

use relic_smt::cli::Args;
use relic_smt::coordinator::{
    run_native_kernel, Backend, Coordinator, Deadline, GraphKernel, Request, RequestResult,
    Router, RouterConfig,
};
use relic_smt::graph::{kronecker_graph, KroneckerParams};
use relic_smt::probe::NoProbe;
use relic_smt::runtime::{GraphExecutor, Manifest};
use relic_smt::testutil::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
    let n_requests = args.get_u64("requests", 256) as usize;

    // --- Load the AOT artifacts (L1/L2 outputs) ------------------------
    let manifest = Manifest::load(Path::new(&artifacts))
        .map_err(|e| anyhow::anyhow!("{e:#}\nrun `make artifacts` first"))?;
    let executor = GraphExecutor::new(Path::new(&artifacts))?;
    println!(
        "PJRT platform: {}; artifacts: {:?}",
        executor.platform(),
        executor.available()
    );

    // --- Validate PJRT vs native on every kernel -----------------------
    validate(&artifacts)?;

    // --- Build the request stream --------------------------------------
    // Mix: 32-vertex graphs (PJRT-eligible) and 64-vertex graphs (no
    // artifact -> native, Relic-paired).
    let mut rng = Rng::new(7);
    let kernels = GraphKernel::all();
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| {
            let scale = if rng.chance(0.5) { 5 } else { 6 };
            let g = kronecker_graph(&KroneckerParams::gap(scale, 16, rng.next_u64() % 1000));
            Request {
                id: i as u64,
                kernel: kernels[rng.range(0, kernels.len())],
                source: rng.below(g.num_vertices() as u64) as u32,
                graph: g,
                deadline: Deadline::none(),
            }
        })
        .collect();

    // --- Serve ----------------------------------------------------------
    let router = Router::new(RouterConfig::default(), Some(&manifest));
    let mut coord = Coordinator::with_parts(router, Some(executor));
    coord.warmup(); // compile all executables before timing
    let t0 = Instant::now();
    let responses = coord.process_batch(requests);
    let dt = t0.elapsed();

    let pjrt = responses.iter().filter(|r| r.backend == Backend::Pjrt).count();
    let native = responses.len() - pjrt;
    println!("\n--- E2E results ---");
    println!(
        "{} requests in {:?}  ({:.0} req/s)",
        responses.len(),
        dt,
        responses.len() as f64 / dt.as_secs_f64()
    );
    println!("routing: {pjrt} PJRT, {native} native (Relic-paired)");
    println!("{}", coord.report());
    Ok(())
}

/// PJRT outputs must agree with the native kernels on a shared input —
/// the cross-layer correctness gate.
fn validate(artifacts: &str) -> anyhow::Result<()> {
    use relic_smt::graph::{dense, kronecker::paper_graph};
    let mut exec = GraphExecutor::new(Path::new(artifacts))?;
    let g = paper_graph();
    let n = g.num_vertices();

    // PageRank: elementwise compare.
    let pjrt_pr = exec.execute("pagerank", n, &[dense::transition(&g), dense::uniform(n)])?;
    let native_pr = relic_smt::graph::pr::pagerank(&g, 20, 0.0, &mut NoProbe);
    let e = max_err(&pjrt_pr, &native_pr);
    anyhow::ensure!(e < 1e-4, "pagerank diverges: {e}");

    // BFS depths (inf -> u32::MAX).
    let pjrt_bfs = exec.execute("bfs", n, &[dense::adjacency(&g), dense::one_hot(n, 0)])?;
    let native_bfs = relic_smt::graph::bfs::bfs(&g, 0, &mut NoProbe);
    for (v, (p, nn)) in pjrt_bfs.iter().zip(&native_bfs).enumerate() {
        let p = if p.is_infinite() { u32::MAX } else { *p as u32 };
        anyhow::ensure!(p == *nn, "bfs diverges at vertex {v}: {p} vs {nn}");
    }

    // SSSP distances.
    let pjrt_sssp =
        exec.execute("sssp", n, &[dense::weights_inf(&g), dense::one_hot(n, 0)])?;
    let native_sssp = relic_smt::graph::sssp::delta_stepping(
        &g,
        0,
        relic_smt::graph::sssp::DEFAULT_DELTA,
        &mut NoProbe,
    );
    for (v, (p, nn)) in pjrt_sssp.iter().zip(&native_sssp).enumerate() {
        let p = if p.is_infinite() { u32::MAX } else { *p as u32 };
        anyhow::ensure!(p == *nn, "sssp diverges at vertex {v}: {p} vs {nn}");
    }

    // CC labels.
    let pjrt_cc = exec.execute("cc", n, &[dense::w0(&g)])?;
    let native_cc = relic_smt::graph::cc::shiloach_vishkin(&g, &mut NoProbe);
    for (v, (p, nn)) in pjrt_cc.iter().zip(&native_cc).enumerate() {
        anyhow::ensure!(*p as u32 == *nn, "cc diverges at vertex {v}");
    }

    // Triangle count.
    let pjrt_tc = exec.execute("tc", n, &[dense::adjacency(&g)])?;
    let native_tc = relic_smt::graph::tc::triangle_count(&g, &mut NoProbe);
    anyhow::ensure!(
        pjrt_tc[0] as u64 == native_tc,
        "tc diverges: {} vs {native_tc}",
        pjrt_tc[0]
    );

    // BC scores.
    let pjrt_bc = exec.execute("bc", n, &[dense::adjacency(&g)])?;
    let native_bc = relic_smt::graph::bc::brandes(&g, &mut NoProbe);
    let e = max_err(&pjrt_bc, &native_bc);
    anyhow::ensure!(e < 1e-2, "bc diverges: {e}");

    println!("validation: PJRT outputs match native kernels on all 6 graph kernels");
    Ok(())
}

fn max_err(a: &[f32], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (*x as f64 - y).abs())
        .fold(0.0, f64::max)
}

#[allow(dead_code)]
fn unused(_: RequestResult) {}
