//! Reproduce every figure and table in the paper in one run.
//!
//! Run: `cargo run --release --example reproduce_paper`
//!
//! Emits, with the paper's reported values beside ours:
//!   §IV  task-granularity table;
//!   Fig. 1  speedups for the seven baseline frameworks;
//!   §V   geomeans including degradations;
//!   Fig. 3  Relic speedups;
//!   Fig. 4  averages without negative outliers.

use relic_smt::bench::figures;
use relic_smt::smtsim::CoreConfig;

fn main() {
    let cfg = CoreConfig::default();

    println!("=== §IV: serial task granularities ===\n");
    println!("{}", figures::render_granularity(&figures::granularity(&cfg)));

    println!("=== Figure 1: baseline frameworks ===\n");
    let f1 = figures::fig1(&cfg);
    println!("{}", figures::render_matrix(&f1));

    println!("=== §V geomeans (with degradations) ===\n");
    println!(
        "{}",
        figures::render_summary(&figures::section5_geomeans(&f1), "")
    );

    println!("=== Figure 3: Relic ===\n");
    let f3 = figures::fig3(&cfg);
    println!("{}", figures::render_matrix(&f3));

    println!("=== Figure 4: averages w/o negative outliers ===\n");
    let f4 = figures::fig4(&f1, &f3);
    println!("{}", figures::render_summary(&f4, ""));

    // Headline check: Relic's relative gain over each baseline.
    let relic = f4.iter().find(|r| r.runtime == "relic").unwrap().value;
    println!("Relic's relative gain over each baseline (paper: 19.1–33.2%):");
    for row in &f4 {
        if row.runtime == "relic" {
            continue;
        }
        println!(
            "  vs {:<14} +{:.1}%",
            row.runtime,
            (relic / row.value - 1.0) * 100.0
        );
    }
}
